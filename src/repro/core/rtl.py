"""Calyx IR -> structural netlist: the "last mile" the paper is named for.

``calyx.Component`` is still a *control-tree* artifact: groups carry
latencies and micro-ops, but nothing is yet a state machine or a wire.
This module lowers a component (plus the affine program's memory
declarations) to a :class:`Netlist` — the FSM + datapath netlist a real
Calyx/CIRCT backend would hand to SystemVerilog emission:

* **Controllers** — the control tree is compiled into explicit FSMs
  (:class:`Fsm` / :class:`FsmState`) the way Calyx's top-down control
  compilation does: ``seq`` chains states, ``repeat`` becomes a setup
  state, the body chain, and an iterate state with an index counter and a
  back-edge, ``if`` becomes a condition-evaluation state that branches
  into two arms padded to the worst-case arm latency (the statically
  timed ``if`` the estimator and simulator agree on), and ``par`` becomes
  a fork/join state that activates one *child FSM per port-conflict
  component* (`estimator.par_conflict_components` — arms that fight over
  a single-ported bank are chained inside one child, conflict-free
  components run concurrently) followed by a join-handshake wait.
  Because every state's duration is a compile-time constant, the whole
  controller's schedule is static — RTL-measured cycles provably equal
  ``estimator.cycles``.

* **Datapath blocks** — each group's micro-ops (``Group.uops``) become a
  :class:`DpBlock` of netlist operations over group-local wires: unit
  invocations resolved to physical :class:`UnitInst` instances (with a
  *grant slot* when the unit is a shared pool produced by
  ``sharing.share_cells`` — the slot indexes the operand muxes recorded
  as :class:`OperandMux`), register reads/writes, and memory port
  accesses with their in-group cycle offsets.

* **Memories** — every logical memory becomes one single-ported
  :class:`BankInst` per bank (:class:`MemSpec` keeps the logical->bank
  mapping), preserving the one-access-per-cycle port discipline that the
  banking story rests on.

The netlist is what ``verilog.emit`` prints as synthesizable SystemVerilog
and what ``rtl_sim.simulate`` executes cycle-by-cycle — closing the
four-way differential harness (RTL ≡ Calyx-sim ≡ affine interp ≡ jnp
oracle, RTL cycles ≡ estimate).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from . import dataflow as D
from . import estimator
from . import float_lib as F
from . import trace as T
from .affine import Cond, Program
from .calyx import (CIf, CNode, CPar, CRepeat, CSeq, Component, GEnable)

# Host-bus bank id that selects the synthesized perf-counter bank
# (profile=True netlists only).  Data banks are numbered 0..n-1, so the
# top of the 16-bit bank space can never collide with one.
PROFILE_HOST_BANK = 0xFFFF

# Operand count per shareable/datapath unit kind — sizes the operand-mux
# trees a pooled unit needs (one mux tree per operand).
UNIT_OPERANDS: Dict[str, int] = {
    "fp_add": 2, "fp_sub": 2, "fp_mul": 2, "fp_div": 2,
    "fp_max": 2, "fp_min": 2,
    "fp_exp": 1, "fp_relu": 1, "fp_neg": 1,
    "int_mul": 1, "int_divmod": 1,
}


def unit_latency(kind: str, const: int = 0) -> int:
    """Pipeline depth of one datapath unit — mirrors float_lib exactly."""
    if kind in F.FLOAT_COSTS:
        return F.FLOAT_COSTS[kind].cycles
    if kind == "int_mul":
        return F.int_mul_cost(const).cycles
    if kind == "int_divmod":
        return F.int_divmod_cost(const).cycles
    if kind in F.INT_COSTS:
        return F.INT_COSTS[kind].cycles
    raise KeyError(kind)


# ---------------------------------------------------------------------------
# Structure: memories, registers, units, muxes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BankInst:
    """One physical single-ported memory bank (1 access / cycle)."""
    name: str
    mem: str                  # logical memory this bank belongs to
    index: int                # bank number within the logical memory
    words: int


@dataclasses.dataclass
class MemSpec:
    """Logical memory -> physical bank mapping."""
    name: str
    shape: Tuple[int, ...]    # declared (banked) shape
    banks: Tuple[int, ...]    # cyclic factors; () = unbanked
    role: str                 # input | param | temp | output
    orig_shape: Optional[Tuple[int, ...]]
    bank_names: List[str]
    intra: Tuple[int, ...]    # per-bank logical shape

    @property
    def words(self) -> int:
        out = 1
        for s in self.intra:
            out *= s
        return out

    def row_strides(self) -> Tuple[int, ...]:
        """Word strides flattening one bank's ``intra`` shape — the single
        source of the bank layout for both the RTL simulator and the
        Verilog address expressions."""
        strides: List[int] = []
        s = 1
        for d in reversed(self.intra):
            strides.insert(0, s)
            s *= d
        return tuple(strides)


@dataclasses.dataclass
class RegInst:
    """64-bit data register (reg32 cell widened to the sim's f64 datapath)."""
    name: str                 # signal name (reg_<x>)
    reg: str                  # micro-op-level register key


@dataclasses.dataclass
class IndexReg:
    """Loop index counter owned by one FSM controller.

    Index registers are *per controller*, not global: two concurrent
    ``par`` arms may each run a repeat over the same source-level loop
    variable (the scheduler clones arm bodies without renaming), and in
    hardware each arm's controller owns its own physical counter.  Name
    resolution for datapath address expressions walks the controller
    parent chain (see :meth:`Netlist.resolve_index`).
    """
    name: str                 # unique signal name
    var: str                  # loop variable it implements
    extent: int               # max value + 1 (sizes the counter)
    fid: int                  # owning controller


@dataclasses.dataclass
class UnitInst:
    """A physical datapath unit instance (possibly a shared pool cell)."""
    name: str
    kind: str
    latency: int
    const: int = 0
    users: int = 1            # grant slots (1 = private)


@dataclasses.dataclass
class OperandMux:
    """Steering mux tree feeding one operand of a shared unit."""
    unit: str
    operand: int              # 0 = a, 1 = b
    fan_in: int               # = unit.users

    @property
    def mux2_count(self) -> int:
        """Equivalent 2:1 muxes (chain depth of the steering tree)."""
        return max(0, self.fan_in - 1)


# ---------------------------------------------------------------------------
# Datapath blocks (per group)
# ---------------------------------------------------------------------------


class DpOp:
    """Base class for netlist datapath operations (SSA over group wires)."""


@dataclasses.dataclass
class DpConst(DpOp):
    dst: int
    value: float


@dataclasses.dataclass
class DpRegRead(DpOp):
    dst: int
    reg: str


@dataclasses.dataclass
class DpMemRead(DpOp):
    dst: int
    mem: str
    idxs: list                # AExpr per dimension (bank dim first if banked)
    off: int                  # cycle offset of the port access in the group


@dataclasses.dataclass
class DpUnit(DpOp):
    dst: int
    unit: str                 # UnitInst name
    op: str
    a: int
    b: Optional[int]
    grant: int = -1           # slot in the unit's operand muxes; -1 = private
    off: int = 0              # cycle offset at which the unit starts


@dataclasses.dataclass
class DpSelect(DpOp):
    dst: int
    cond: Cond
    a: int
    b: int
    off: int = 0              # cycle offset at which the mux selects


@dataclasses.dataclass
class DpRegWrite(DpOp):
    reg: str
    src: int
    off: int = 0              # cycle offset at which the register latches


@dataclasses.dataclass
class DpMemWrite(DpOp):
    mem: str
    idxs: list
    src: int
    off: int


@dataclasses.dataclass
class DpBlock:
    """One group's datapath as netlist operations."""
    group: str
    latency: int
    ops: List[DpOp]
    pooled_units: List[str]   # shared UnitInsts this block takes a grant on


# ---------------------------------------------------------------------------
# Controllers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FsmState:
    """One explicit controller state.

    ``kind``:
      * ``group`` — assert the group's go for ``cycles`` cycles.
      * ``delay`` — pure wait (loop setup/iterate, if-arm padding).
      * ``cond``  — evaluate ``cond`` over the index registers during
        ``cycles`` cycles, then branch to ``then_state``/``else_state``.
      * ``par``   — fork the child FSMs in ``children``, wait for all
        their dones, then wait ``join_cycles`` for the join reduction.
      * ``pipe``  — pipelined repeat (``CRepeat.ii > 0``): re-launch the
        body group every ``ii`` cycles, incrementing the loop index at
        each launch; the state lasts ``(extent-1)*ii + latency`` cycles
        (``cycles``) so the last iteration fully drains.  ``pipe`` holds
        ``(var, extent, ii, body_latency)``.
      * ``done``  — terminal; raises the FSM's done signal.

    Entry/exit actions: ``set_idx`` zeroes an index register at entry;
    ``inc_idx`` increments one at exit; ``loop`` is the repeat back-edge
    (index, extent, head-state) taken while ``index < extent``.
    """
    index: int
    kind: str
    cycles: int = 0
    label: str = ""
    group: Optional[str] = None
    next: Optional[int] = None
    set_idx: Optional[str] = None
    inc_idx: Optional[str] = None
    loop: Optional[Tuple[str, int, int]] = None
    cond: Optional[Cond] = None
    then_state: Optional[int] = None
    else_state: Optional[int] = None
    children: List[int] = dataclasses.field(default_factory=list)
    join_cycles: int = 0
    pipe: Optional[Tuple[str, int, int, int]] = None  # var, extent, ii, lat
    # observability metadata (core.trace provenance discipline) — stamped
    # at lowering time so the netlist simulator emits join-able events and
    # the Verilog emitter can synthesize the stall counters:
    prov: Tuple[str, ...] = ()
    # entry state of a serialized par-chain member p>0: (arm path, p);
    # the member waited behind its port-conflicting siblings
    stall_arm: Optional[Tuple[Tuple[str, ...], int]] = None
    # per-cycle port-stall weight: a chain member followed by w siblings
    # delays each of them one cycle per cycle it occupies — summing
    # w * residence over all states equals the serialization loss
    stall_weight: int = 0


@dataclasses.dataclass
class Fsm:
    fid: int
    name: str
    states: List[FsmState]
    start: int
    parent: Optional[int] = None       # forking controller (None = root)
    binds: Dict[str, int] = dataclasses.field(default_factory=dict)
    # loop vars this controller owns -> extent (sizes the index counter)


@dataclasses.dataclass
class PerfCounter:
    """One synthesized 64-bit hardware performance counter.

    Counters live in their own host-bus bank (:data:`PROFILE_HOST_BANK`)
    and are addressed by ``index`` over the existing handshake.  ``kind``:

      * ``total``         — cycles with busy high and done low
      * ``group``         — cycles the named ``group``'s go is high
      * ``stall_port``    — par arms' serialization behind port conflicts
      * ``stall_pool``    — shared-pool grant waits (0 by construction:
        binding keeps a pool inside one serialized chain; the counter
        exists so silicon can falsify that invariant)
      * ``stall_ii``      — pipelined loops' inter-launch wait cycles
      * ``fsm_overhead``  — control states (setup/iter/cond/pad/join)
    """
    index: int
    name: str
    kind: str
    group: Optional[str] = None


@dataclasses.dataclass
class Netlist:
    """Structural FSM + datapath netlist for one component."""
    name: str
    mems: Dict[str, MemSpec]
    banks: Dict[str, BankInst]
    regs: Dict[str, RegInst]
    index_regs: Dict[Tuple[int, str], IndexReg]   # (fid, var) -> counter
    units: Dict[str, UnitInst]
    muxes: List[OperandMux]
    blocks: Dict[str, DpBlock]
    fsms: List[Fsm]            # fsms[0] is the root controller
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)
    counters: List[PerfCounter] = dataclasses.field(default_factory=list)
    profile: bool = False      # synthesize the counter bank + host readout

    def stats(self) -> Dict[str, int]:
        """Netlist-size summary tracked by the benchmark across PRs."""
        return {
            "fsms": len(self.fsms),
            "fsm_states": sum(len(f.states) for f in self.fsms),
            "mux2": sum(m.mux2_count for m in self.muxes),
            "units": len(self.units),
            "banks": len(self.banks),
            "regs": len(self.regs),
            "index_regs": len(self.index_regs),
            "dp_ops": sum(len(b.ops) for b in self.blocks.values()),
        }

    def group_fids(self) -> Dict[str, int]:
        """group -> fid of the controller whose state enables it."""
        out: Dict[str, int] = {}
        for f in self.fsms:
            for st in f.states:
                if st.kind in ("group", "pipe"):
                    out[st.group] = f.fid
        return out

    def resolve_index(self, fid: int, var: str) -> IndexReg:
        """Resolve a loop variable from controller ``fid`` by walking the
        parent chain — the scope discipline both the RTL simulator and
        the Verilog emitter use for address/condition expressions."""
        cur: Optional[int] = fid
        while cur is not None:
            f = self.fsms[cur]
            if var in f.binds:
                return self.index_regs[(cur, var)]
            cur = f.parent
        raise KeyError(f"loop var {var!r} not bound on the controller "
                       f"chain of fsm{fid}")


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

# patch targets: (state_index, field) pairs whose branch target is filled
# in once the continuation state exists
_Exit = Tuple[int, str]


class _FsmBuilder:
    """Compiles one control (sub)tree into one Fsm."""

    def __init__(self, lower: "_RtlLower", parent: Optional[int]):
        self.lower = lower
        self.fid = lower.alloc_fid()
        self.parent = parent
        self.binds: Dict[str, int] = {}
        self.states: List[FsmState] = []

    def add(self, kind: str, **kw) -> int:
        st = FsmState(index=len(self.states), kind=kind, **kw)
        self.states.append(st)
        return st.index

    def patch(self, exits: List[_Exit], target: int) -> None:
        for idx, field in exits:
            setattr(self.states[idx], field, target)

    # -- control-tree compilation -------------------------------------------
    def build(self, node: CNode,
              path: Tuple[str, ...] = ()) -> Tuple[Optional[int],
                                                   List[_Exit]]:
        """Compile ``node``; return (entry state or None-if-empty, exits).

        ``path`` is the node's control-tree provenance chain, stamped onto
        every created state (``FsmState.prov``) with exactly the labels
        the Calyx-level simulator builds at run time (``core.trace``) —
        the key discipline that makes the two simulators' traces join.
        A state's prov excludes its group leaf: group-level events append
        ``state.group`` themselves.
        """
        comp = self.lower.comp
        if isinstance(node, GEnable):
            g = comp.groups[node.group]
            s = self.add("group", cycles=g.latency, group=g.name,
                         label=g.name, prov=path)
            return s, [(s, "next")]
        if isinstance(node, CSeq):
            entry: Optional[int] = None
            exits: List[_Exit] = []
            for k, ch in enumerate(node.children):
                e, x = self.build(ch, path + (T.seq_label(k),))
                if e is None:
                    continue
                if entry is None:
                    entry = e
                else:
                    self.patch(exits, e)
                exits = x
            return entry, exits
        if isinstance(node, CRepeat):
            var = node.var or self.lower.fresh_counter()
            # the trace label keeps the *source* loop var (empty-var loops
            # share the generic label), never the fresh counter name — the
            # Calyx simulator has no access to lowering-time gensyms
            lpath = path + (T.loop_label(node.var),)
            self.binds[var] = max(self.binds.get(var, 0), node.extent)
            setup = self.add("delay", cycles=F.LOOP_SETUP_CYCLES,
                             label="setup", set_idx=var, prov=lpath)
            if node.extent <= 0:
                return setup, [(setup, "next")]
            if node.ii and not isinstance(node.body, GEnable):
                raise ValueError(
                    "pipelined repeat body must be a single group "
                    "(run chaining before pipelining)")
            if node.ii:
                # pipelined repeat: one state re-launches the body group
                # every ii cycles; residence covers the last drain
                g = comp.groups[node.body.group]
                total = (node.extent - 1) * node.ii + g.latency
                ps = self.add("pipe", cycles=total, group=g.name,
                              label=f"pipe ii={node.ii}", prov=lpath,
                              pipe=(var, node.extent, node.ii, g.latency))
                self.patch([(setup, "next")], ps)
                return setup, [(ps, "next")]
            body_e, body_x = self.build(node.body, lpath)
            it = self.add("delay", cycles=F.LOOP_ITER_OVERHEAD, label="iter",
                          inc_idx=var, prov=lpath)
            head = body_e if body_e is not None else it
            self.states[it].loop = (var, node.extent, head)
            self.patch([(setup, "next")], head)
            if body_e is not None:
                self.patch(body_x, it)
            return setup, [(it, "next")]
        if isinstance(node, CIf):
            worst = max(estimator.cycles(comp, node.then),
                        estimator.cycles(comp, node.els))
            ipath = path + (T.IF_LABEL,)
            cs = self.add("cond",
                          cycles=node.cond_latency + F.IF_SELECT_CYCLES,
                          label="cond", cond=node.cond, prov=ipath)
            exits: List[_Exit] = []
            for arm, field, albl in ((node.then, "then_state", T.THEN_LABEL),
                                     (node.els, "else_state", T.ELSE_LABEL)):
                apath = ipath + (albl,)
                pad = worst - estimator.cycles(comp, arm)
                a_entry, a_exits = self.build(arm, apath)
                if pad > 0:
                    p = self.add("delay", cycles=pad, label="pad",
                                 prov=apath)
                    if a_entry is None:
                        a_entry = p
                    else:
                        self.patch(a_exits, p)
                    a_exits = [(p, "next")]
                if a_entry is None:
                    exits.append((cs, field))      # empty zero-pad arm
                else:
                    setattr(self.states[cs], field, a_entry)
                    exits += a_exits
            return cs, exits
        if isinstance(node, CPar):
            arms = node.children
            if not arms:
                return None, []
            ppath = path + (T.PAR_LABEL,)
            comps = estimator.par_conflict_components(comp, node)
            children: List[int] = []
            for members in comps:
                chain = [(arms[i], ppath + (T.arm_label(i),))
                         for i in members]
                children.append(self.lower.child_fsm_chain(chain, self.fid))
            ps = self.add("par", label="par", children=children,
                          join_cycles=estimator.par_join_cycles(len(arms)),
                          prov=ppath)
            return ps, [(ps, "next")]
        raise TypeError(node)

    def build_chain(self, chain: List[Tuple[CNode, Tuple[str, ...]]]
                    ) -> Tuple[Optional[int], List[_Exit]]:
        """Compile one par conflict component: the member arms serialize
        back to back, each keeping its own arm provenance.

        Stall bookkeeping for the port-conflict serialization: member p's
        states carry ``stall_weight = members_after_p`` (each of its
        residence cycles delays that many siblings — summed over the run
        this equals the cumulative-wait loss), and the entry state of
        each delayed member records ``stall_arm = (arm_path, p)`` so the
        netlist simulator can emit the event the Calyx simulator emits.
        Nested controllers forked from inside a member are intentionally
        left unstamped: the member's own (weighted) par state stays
        resident while they run.
        """
        n = len(chain)
        entry: Optional[int] = None
        exits: List[_Exit] = []
        for p, (node, apath) in enumerate(chain):
            lo = len(self.states)
            e, x = self.build(node, apath)
            weight = n - 1 - p
            if weight > 0:
                for st in self.states[lo:]:
                    st.stall_weight = weight
            if e is None:
                continue
            if p > 0:
                self.states[e].stall_arm = (apath, p)
            if entry is None:
                entry = e
            else:
                self.patch(exits, e)
            exits = x
        return entry, exits

    def finish(self, node: CNode, path: Tuple[str, ...] = ()) -> Fsm:
        entry, exits = self.build(node, path)
        return self._seal(entry, exits)

    def finish_chain(self,
                     chain: List[Tuple[CNode, Tuple[str, ...]]]) -> Fsm:
        entry, exits = self.build_chain(chain)
        return self._seal(entry, exits)

    def _seal(self, entry: Optional[int], exits: List[_Exit]) -> Fsm:
        dn = self.add("done", label="done")
        if entry is None:
            entry = dn
        else:
            self.patch(exits, dn)
        return Fsm(fid=self.fid, name=f"fsm{self.fid}", states=self.states,
                   start=entry, parent=self.parent, binds=self.binds)


class _RtlLower:
    def __init__(self, comp: Component, prog: Program,
                 profile: bool = False):
        self.comp = comp
        self.prog = prog
        self.profile = profile
        self.fsms: List[Optional[Fsm]] = []
        self._counter = 0
        # pooled unit -> group -> grant slot (first-use order)
        self.grants: Dict[str, Dict[str, int]] = {}

    # -- FSM bookkeeping ----------------------------------------------------
    def alloc_fid(self) -> int:
        self.fsms.append(None)
        return len(self.fsms) - 1

    def child_fsm(self, node: CNode, parent: int,
                  path: Tuple[str, ...] = ()) -> int:
        builder = _FsmBuilder(self, parent)
        self.fsms[builder.fid] = builder.finish(node, path)
        return builder.fid

    def child_fsm_chain(self, chain: List[Tuple[CNode, Tuple[str, ...]]],
                        parent: int) -> int:
        builder = _FsmBuilder(self, parent)
        self.fsms[builder.fid] = builder.finish_chain(chain)
        return builder.fid

    def fresh_counter(self) -> str:
        self._counter += 1
        return f"_rpt{self._counter}"

    # -- datapath ------------------------------------------------------------
    def grant_slot(self, unit: str, group: str) -> int:
        slots = self.grants.setdefault(unit, {})
        return slots.setdefault(group, len(slots))

    def lower_block(self, gname: str) -> DpBlock:
        g = self.comp.groups[gname]
        ops: List[DpOp] = []
        pooled: List[str] = []
        for u in g.uops:
            if isinstance(u, D.UConst):
                ops.append(DpConst(u.dst, u.value))
            elif isinstance(u, D.URegRead):
                ops.append(DpRegRead(u.dst, u.reg))
            elif isinstance(u, D.UMemRead):
                ops.append(DpMemRead(u.dst, u.mem, list(u.idxs), u.off))
            elif isinstance(u, D.UAlu):
                cell = self.comp.cells.get(u.cell)
                grant = -1
                if cell is not None and cell.users > 1:
                    grant = self.grant_slot(u.cell, gname)
                    if u.cell not in pooled:
                        pooled.append(u.cell)
                ops.append(DpUnit(u.dst, u.cell, u.op, u.a, u.b, grant,
                                  u.off))
            elif isinstance(u, D.USelect):
                ops.append(DpSelect(u.dst, u.cond, u.a, u.b, u.off))
            elif isinstance(u, D.URegWrite):
                ops.append(DpRegWrite(u.reg, u.src, u.off))
            elif isinstance(u, D.UMemWrite):
                ops.append(DpMemWrite(u.mem, list(u.idxs), u.src, u.off))
            else:
                raise TypeError(u)
        return DpBlock(gname, g.latency, ops, pooled)

    # -- top-level -----------------------------------------------------------
    def run(self) -> Netlist:
        # memories -> banks
        mems: Dict[str, MemSpec] = {}
        banks: Dict[str, BankInst] = {}
        orig_shapes = self.prog.meta.get("orig_shapes", {})
        for name, decl in self.prog.mems.items():
            if decl.banks:
                nbanks = decl.shape[0]
                intra = tuple(decl.shape[1:])
                bank_names = [f"mem_{name}_b{b}" for b in range(nbanks)]
            else:
                intra = tuple(decl.shape)
                bank_names = [f"mem_{name}"]
            spec = MemSpec(name, tuple(decl.shape), tuple(decl.banks),
                           decl.role, tuple(orig_shapes.get(name, ())) or None,
                           bank_names, intra)
            mems[name] = spec
            for b, bn in enumerate(bank_names):
                banks[bn] = BankInst(bn, name, b, spec.words)

        # cells -> registers and datapath units
        regs: Dict[str, RegInst] = {}
        units: Dict[str, UnitInst] = {}
        for cell in self.comp.cells.values():
            if cell.kind == "mem_bank":
                continue                      # already built from the decls
            if cell.kind == "reg32":
                key = cell.name[len("reg_"):] if \
                    cell.name.startswith("reg_") else cell.name
                regs[key] = RegInst(cell.name, key)
            elif cell.kind == "idx_reg":
                continue                      # controller-owned (note_index)
            else:
                units[cell.name] = UnitInst(
                    cell.name, cell.kind,
                    unit_latency(cell.kind, cell.const),
                    cell.const, cell.users)

        # datapath blocks (also populates the grant tables)
        blocks = {g: self.lower_block(g) for g in self.comp.groups}

        # controllers: the root builder allocates fid 0 before any par
        # state forks a child, so fsms[0] is the root by construction
        root_builder = _FsmBuilder(self, None)
        self.fsms[root_builder.fid] = root_builder.finish(self.comp.control)

        # per-controller index counters; signal names carry the fsm suffix
        # only when the same loop var is bound by more than one controller
        index_regs: Dict[Tuple[int, str], IndexReg] = {}
        var_owners: Dict[str, int] = {}
        for f in self.fsms:
            for var in f.binds:
                var_owners[var] = var_owners.get(var, 0) + 1
        for f in self.fsms:
            for var, extent in f.binds.items():
                name = f"idx_{var}" if var_owners[var] == 1 \
                    else f"idx_{var}_f{f.fid}"
                index_regs[(f.fid, var)] = IndexReg(name, var, extent, f.fid)

        muxes: List[OperandMux] = []
        for uname, unit in units.items():
            if unit.users > 1:
                for op_i in range(UNIT_OPERANDS.get(unit.kind, 2)):
                    muxes.append(OperandMux(uname, op_i, unit.users))

        meta = dict(self.comp.meta)
        meta["component"] = self.comp.name
        counters: List[PerfCounter] = []
        if self.profile:
            counters = perf_counter_bank(blocks)
        return Netlist(self.comp.name, mems, banks, regs, index_regs,
                       units, muxes, blocks,
                       [f for f in self.fsms if f is not None], meta,
                       counters, self.profile)


def perf_counter_bank(blocks: Dict[str, DpBlock]) -> List[PerfCounter]:
    """The canonical counter layout for a profiled netlist: index 0 is
    the total-cycle counter, then one per group in block order, then the
    four stall/overhead counters.  The layout is a function of the group
    set alone so hosts can derive the address map from the design."""
    counters = [PerfCounter(0, "perf_total", "total")]
    for g in blocks:
        counters.append(PerfCounter(len(counters), f"perf_g_{g}", "group",
                                    group=g))
    for kind in ("stall_port", "stall_pool", "stall_ii", "fsm_overhead"):
        counters.append(PerfCounter(len(counters), f"perf_{kind}", kind))
    return counters


def lower_component(comp: Component, prog: Program,
                    profile: bool = False) -> Netlist:
    """Lower a Calyx component (plus its program's memory declarations)
    to the structural FSM + datapath netlist.  ``profile=True`` also
    synthesizes the hardware perf-counter bank (read over the host bus
    at bank :data:`PROFILE_HOST_BANK`); the observability metadata on
    the FSM states (provenance, stall weights) is stamped either way —
    only the counter hardware is gated.
    """
    for g in comp.groups.values():
        if not g.uops:
            raise ValueError(
                f"[RV007] group {g.name} carries no micro-ops — re-lower "
                f"with calyx.lower_program before the RTL backend")
    return _RtlLower(comp, prog, profile).run()
