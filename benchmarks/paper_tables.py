"""Reproduction of the paper's evaluation (Figs 2-3, Tables 1-2).

Vitis HLS cannot run in this environment; the paper's published Vitis and
Calyx numbers are embedded as reference constants and printed next to our
Calyx-flow estimates so the regimes and ratios are directly comparable.

All compiles here pass ``share=False``: the paper's toolchain has no
binding stage (resource sharing is its future work), so its Table 1/2
resource numbers correspond to one-unit-per-statement designs.  The
shared-vs-unshared column lives in benchmarks/banking_ablation.py.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import frontend, pipeline

# Published numbers (paper §4).  Fig. 3 latencies; Tables 1-2 resources.
PAPER = {
    "ffnn_cycles": {1: 22475, 2: 9378, 4: 3078},
    "ffnn_vitis_cycles": {2: 7908, 4: 6813},
    "speedup_1_2": 2.40,
    "speedup_2_4": 3.05,
    "table1": {  # resource: model: (vitis, calyx)
        "LUTs": {"MHA": (7846, 33312), "CNN": (3136, 4574),
                 "FFNN": (2011, 3730)},
        "FFs": {"MHA": (4017, 5561), "CNN": (1815, 1223),
                "FFNN": (1281, 742)},
        "BRAMs": {"MHA": (194, 71), "CNN": (213, 43), "FFNN": (43, 9)},
        "DSPs": {"MHA": (19, 67), "CNN": (5, 14), "FFNN": (5, 6)},
    },
    "table2_calyx": {  # FFNN resources vs partition factor
        "LUTs": {1: 3730, 2: 13197, 4: 49121},
        "FFs": {1: 742, 2: 3145, 4: 10657},
        "BRAMs": {1: 9, 2: 10, 4: 20},
        "DSPs": {1: 6, 2: 20, 4: 69},
    },
}


def _models():
    return {
        "FFNN": (frontend.paper_ffnn(), (1, 64)),
        "CNN": (frontend.paper_cnn(), (3, 80, 60)),
        "MHA": (frontend.paper_mha(), (8, 42)),
    }


def fig2_latency(emit) -> Dict[str, Dict]:
    """Baseline (factor 1) latency across the three models."""
    out = {}
    for name, (model, shape) in _models().items():
        t0 = time.time()
        d = pipeline.compile_model(model, [shape], factor=1,
                                   share=False)
        wall = (time.time() - t0) * 1e6
        est = d.estimate
        out[name] = est.as_dict()
        emit(f"fig2_{name.lower()}_cycles", wall, est.cycles)
        emit(f"fig2_{name.lower()}_wall_us", wall, est.wall_us)
    return out


def table1_resources(emit) -> Dict[str, Dict]:
    out = {}
    for name, (model, shape) in _models().items():
        d = pipeline.compile_model(model, [shape], factor=1,
                                   share=False)
        res = d.estimate.resources
        out[name] = res
        for r, ours in res.items():
            key = {"LUT": "LUTs", "FF": "FFs", "BRAM": "BRAMs",
                   "DSP": "DSPs"}[r]
            vitis, calyx = PAPER["table1"][key][name]
            emit(f"table1_{name.lower()}_{r.lower()}", 0.0,
                 f"{ours}|paper_calyx={calyx}|paper_vitis={vitis}")
    return out


def fig3_partition_sweep(emit) -> Dict[int, Dict]:
    """FFNN latency + resources vs cyclic partition factor (the headline)."""
    model, shape = _models()["FFNN"]
    results = {}
    for f in (1, 2, 4):
        t0 = time.time()
        d = pipeline.compile_model(model, [shape], factor=f,
                                   share=False)
        wall = (time.time() - t0) * 1e6
        results[f] = d.estimate.as_dict()
        emit(f"fig3_ffnn_f{f}_cycles", wall,
             f"{d.estimate.cycles}|paper={PAPER['ffnn_cycles'][f]}")
        for r, v in d.estimate.resources.items():
            key = {"LUT": "LUTs", "FF": "FFs", "BRAM": "BRAMs",
                   "DSP": "DSPs"}[r]
            emit(f"table2_ffnn_f{f}_{r.lower()}", 0.0,
                 f"{v}|paper={PAPER['table2_calyx'][key][f]}")
    s12 = results[1]["cycles"] / results[2]["cycles"]
    s24 = results[2]["cycles"] / results[4]["cycles"]
    emit("fig3_speedup_1to2", 0.0,
         f"{s12:.2f}|paper={PAPER['speedup_1_2']}")
    emit("fig3_speedup_2to4", 0.0,
         f"{s24:.2f}|paper={PAPER['speedup_2_4']}")
    return results


def run(emit) -> None:
    fig2_latency(emit)
    table1_resources(emit)
    fig3_partition_sweep(emit)
