"""Pallas kernel microbenches (interpret mode on CPU — correctness-scale
timings; the roofline story for real hardware lives in §Roofline).

Reports us_per_call and the bank-derived block geometry, plus the
reference-path timing for context.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.banked_matmul import derive_block


def _time(fn, *args, iters=3) -> float:
    out = jax.block_until_ready(fn(*args))   # compile
    t0 = time.time()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run(emit) -> None:
    rng = np.random.default_rng(0)

    # banked matmul: factor sweep mirrors the paper's partition sweep
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    for banks in ((1, 1, 1), (2, 2, 2), (4, 4, 4)):
        us = _time(lambda x, y: ops.matmul(x, y, banks=banks), a, b)
        blk = derive_block(256, 256, 256, banks)
        emit(f"kernel_matmul_banks{banks[0]}", us, f"block={blk}")
    emit("kernel_matmul_ref", _time(ref.matmul_ref, a, b), "jnp_oracle")

    # flash attention
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    us = _time(lambda *t: ops.attention(*t, causal=True, block_q=64,
                                        block_k=64), q, k, v)
    emit("kernel_flash_attention", us, "gqa4:2_s256_d64")
    emit("kernel_attention_ref", _time(
        lambda *t: ref.attention_ref(*t, causal=True), q, k, v), "jnp_oracle")

    # decay scan (Mamba2 + RWKV modes)
    q2 = jnp.asarray(rng.normal(size=(1, 4, 256, 32)), jnp.float32)
    k2 = jnp.asarray(rng.normal(size=(1, 4, 256, 32)), jnp.float32)
    v2 = jnp.asarray(rng.normal(size=(1, 4, 256, 32)), jnp.float32)
    w2 = jnp.asarray(-np.abs(rng.normal(size=(1, 4, 256, 32))) * 0.2,
                     jnp.float32)
    u2 = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    emit("kernel_ssm_scan_inclusive",
         _time(lambda *t: ops.decay_scan(*t, chunk=32), q2, k2, v2, w2),
         "mamba2_mode")
    emit("kernel_ssm_scan_bonus",
         _time(lambda *t: ops.decay_scan(*t, u=u2, chunk=32,
                                         diag_mode="bonus"), q2, k2, v2, w2),
         "rwkv6_mode")
    emit("kernel_ssm_scan_ref", _time(
        lambda *t: ref.ssm_scan_ref(*t), q2, k2, v2, w2), "jnp_oracle")
