"""Model-operator profiling harness -> BENCH_model.json.

Per architecture (dense transformer + SSM + MoE — three model families),
replays a seeded traffic trace through the *profiled* serving engine
(``Engine(layers=LayerProfiler())`` — the sliced per-operator decode step
of ``models.decode.ProfiledServeStep``) and records:

* **flame**: mean wall per (operator, group) and each operator kind's
  share of profiled step time — the measured half of the offload ranking;
* **record overhead** (gated <= 5%): two profiled-mode engines — one with
  ``LayerProfiler(record=False)``, one recording — driven through the
  identical schedule in lockstep (one tick each, alternating who goes
  first), so every off/on wall pair is milliseconds apart and load drift
  cancels.  Both sides run the sliced step, so the pair isolates the cost
  of *recording* from the cost of *slicing* — the same separation PR 8's
  span contract drew between tracing hooks and the engine's inherent
  per-step sync;
* **slice overhead** (informational, not gated): fused engine vs profiled
  ``record=False`` engine in the same lockstep protocol.  Slicing costs
  real wall time (lost XLA fusion, one dispatch + ``block_until_ready``
  per segment) and that cost is *inherent to per-operator attribution*,
  not to the recording layer; on the tiny reduced configs it is large
  relative to a sub-millisecond step and shrinks as model compute grows;
* **join**: a spans+layers run must close the three-level trace — every
  engine-step span maps to a complete, in-order per-layer record set
  (``modelprof.join_mismatches`` empty) — and ``coverage`` (summed
  segment walls / step wall) is reported as p50/min/max;
* **determinism**: two same-seed recording runs must serialize
  byte-identically in the layer exporter's stable mode;
* **crosscheck**: the analytic per-op cost model vs
  ``hlo_analysis.analyze`` on the decode-step HLO at the engine's exact
  shapes (flops within ``modelprof.FLOPS_RTOL``, bytes within the
  ``BYTES_FACTOR`` band);
* **offload**: ``modelprof.offload_report`` — operators ranked by
  measured share, annotated with analytic FLOPs/bytes/intensity at the
  *full* (unreduced) config and production cache length, roofline-classed
  against the device peaks.  This table is ROADMAP item 1's work order:
  which kernels to lower to Calyx first.

Environment overrides: ``MODEL_BENCH_ARCHS`` restricts the matrix (CI
runs the smallest arch), ``MODEL_BENCH_OUT`` moves the JSON,
``MODEL_BENCH_REPEATS`` sets the lockstep pool, ``MODEL_BENCH_LAYERS_DIR``
additionally writes the stable layer JSONL per arch as artifacts.

``scripts/check_perf_regression.py --model-*`` gates BENCH_model.json:
record overhead < 5% exact, per-op walls at a loose cross-machine
tolerance, analytic/HLO cross-check exact.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.launch.serve import Engine, ReplayDriver, Request
from repro.models import get_config
from repro.models import params as MP
from repro.models.decode import profile_ops
from repro.obs import SpanTracer, traffic
from repro.obs import modelprof as MPF
from repro.obs.modelprof import LayerProfiler

SEED = 0

# three model families: dense transformer, RWKV6 SSM, MoE
ARCHS = ("qwen2-0.5b", "rwkv6-7b", "olmoe-1b-7b")

PROFILE = dict(requests=6, slots=2, mean_interarrival=0.5,
               prompt_lens=(4, 8), gen_lens=(8, 12))

# deployment shape for the analytic offload columns: the full (unreduced)
# config serving one stream against a production cache span
FULL_BATCH = 1
FULL_CACHE_LEN = 4096


def _build_arrivals(cfg, trace, seed: int) -> List[Tuple[int, Request]]:
    rng = np.random.default_rng(seed + 1)
    return [(t.arrival_step,
             Request(t.rid,
                     rng.integers(1, cfg.vocab_size,
                                  size=t.prompt_len).astype(np.int32),
                     t.gen_len))
            for t in trace]


def _max_len(trace) -> int:
    return traffic.total_tokens(trace) \
        + max((t.prompt_len + t.gen_len for t in trace), default=0) + 8


def _make_driver(cfg, params, trace, seed: int,
                 spans: Optional[SpanTracer] = None,
                 layers: Optional[LayerProfiler] = None) -> ReplayDriver:
    eng = Engine(cfg, params, PROFILE["slots"], _max_len(trace),
                 spans=spans, layers=layers)
    return ReplayDriver(eng, _build_arrivals(cfg, trace, seed))


def _lockstep(mk_a, mk_b) -> Tuple[Engine, Engine,
                                   List[float], List[float]]:
    """Drive two engine factories through the identical schedule one tick
    at a time, alternating who goes first; returns per-tick walls."""
    a, b = mk_a(), mk_b()
    walls_a: List[float] = []
    walls_b: List[float] = []
    k = 0
    while a.active or b.active:
        first, second = (a, b) if k % 2 == 0 else (b, a)
        for drv in (first, second):
            t0 = time.perf_counter()
            ticked = drv.tick()
            wall = time.perf_counter() - t0
            if ticked:
                (walls_a if drv is a else walls_b).append(wall)
        k += 1
    n = min(len(walls_a), len(walls_b))
    return a.eng, b.eng, walls_a[:n], walls_b[:n]


def _overhead(ticks_base: List[float], ticks_inst: List[float]) -> float:
    """median(paired deltas) / median(base ticks) — load drift cancels."""
    if not ticks_base:
        return 0.0
    med = float(np.median(ticks_base))
    deltas = np.asarray(ticks_inst) - np.asarray(ticks_base)
    return float(np.median(deltas)) / med if med else 0.0


def run(emit, out_path: Optional[str] = None) -> None:
    archs = [a.strip() for a in
             os.environ.get("MODEL_BENCH_ARCHS", "").split(",")
             if a.strip()] or list(ARCHS)
    repeats = max(2, int(os.environ.get("MODEL_BENCH_REPEATS", "3")))
    layers_dir = os.environ.get("MODEL_BENCH_LAYERS_DIR", "")
    if layers_dir:
        os.makedirs(layers_dir, exist_ok=True)
    peaks = MPF.device_peaks()
    records = []
    failures = []
    for arch in archs:
        tag = f"model_profile_{arch}"
        t_section = time.perf_counter()
        full_cfg = get_config(arch)
        cfg = full_cfg.reduced()
        params = MP.init_params(cfg, seed=SEED)
        trace = traffic.synth_trace(SEED, PROFILE["requests"],
                                    PROFILE["mean_interarrival"],
                                    PROFILE["prompt_lens"],
                                    PROFILE["gen_lens"])
        max_len = _max_len(trace)

        # warm both execution modes so no timed tick pays compilation
        warm = traffic.synth_trace(SEED, 2, 0.0, (2,), (2,))
        for layers in (None, LayerProfiler(record=False)):
            drv = _make_driver(cfg, params, warm, SEED, layers=layers)
            while drv.active:
                drv.tick()

        # -- record overhead (gated): sliced+off vs sliced+recording ------
        ticks_off: List[float] = []
        ticks_on: List[float] = []
        stable_streams: List[str] = []
        last_prof: Optional[LayerProfiler] = None
        for _ in range(repeats):
            prof = LayerProfiler()
            e_off, e_on, w_off, w_on = _lockstep(
                lambda: _make_driver(cfg, params, trace, SEED,
                                     layers=LayerProfiler(record=False)),
                lambda: _make_driver(cfg, params, trace, SEED,
                                     layers=prof))
            ticks_off.extend(w_off)
            ticks_on.extend(w_on)
            last_prof = prof
            if e_off.steps != e_on.steps:
                failures.append(f"{tag}: recording run took {e_on.steps} "
                                f"steps, baseline {e_off.steps}")
            if len(stable_streams) < 2:
                stable_streams.append(MPF.to_jsonl(prof.records,
                                                   stable=True))
        assert last_prof is not None
        record_overhead = _overhead(ticks_off, ticks_on)
        deterministic = stable_streams[0] == stable_streams[1]
        if not deterministic:
            failures.append(f"{tag}: stable layer streams of two "
                            f"same-seed runs differ")

        # -- slice overhead (informational): fused vs sliced+off ----------
        _, _, w_fused, w_sliced = _lockstep(
            lambda: _make_driver(cfg, params, trace, SEED),
            lambda: _make_driver(cfg, params, trace, SEED,
                                 layers=LayerProfiler(record=False)))
        slice_overhead = _overhead(w_fused, w_sliced)

        # -- three-level join: spans + layers in one run ------------------
        tr = SpanTracer()
        join_prof = LayerProfiler()
        drv = _make_driver(cfg, params, trace, SEED,
                           spans=tr, layers=join_prof)
        while drv.active:
            drv.tick()
        problems = MPF.validate(join_prof.records, cfg=cfg,
                                engine_steps=drv.eng.steps)
        problems += MPF.join_mismatches(join_prof.records, tr.events,
                                        cfg=cfg)
        if problems:
            failures.append(f"{tag}: three-level join broken "
                            f"(first: {problems[0]})")
        rows = MPF.join_steps(join_prof.records, tr.events)
        coverages = [r.coverage for r in rows.values()
                     if r.step_wall_us > 0]
        cov = {"p50": round(float(np.median(coverages)), 4),
               "min": round(min(coverages), 4),
               "max": round(max(coverages), 4)} if coverages else {}

        # -- analytic vs HLO at the engine's exact shapes -----------------
        crosscheck, cc_problems = MPF.crosscheck_hlo(
            cfg, batch=PROFILE["slots"], cache_len=max_len)
        failures.extend(f"{tag}: {p}" for p in cc_problems)

        # -- flame + offload ranking --------------------------------------
        recs = last_prof.records
        summary = MPF.summarize(recs)
        shares = MPF.op_shares(recs)
        flame = [{"op": op, "group": g,
                  "wall_us_mean": round(s.mean_us, 1),
                  "calls": s.calls}
                 for (op, g), s in sorted(summary.items(),
                                          key=lambda kv: (kv[0][1],
                                                          kv[0][0]))]
        full_costs = MPF.analytic_op_costs(full_cfg, FULL_BATCH,
                                           FULL_CACHE_LEN)
        offload = MPF.offload_report(full_cfg, recs, full_costs,
                                     peaks=peaks)

        rec = {
            "arch": arch,
            "family": cfg.family,
            "seed": SEED,
            "requests": PROFILE["requests"],
            "slots": PROFILE["slots"],
            "cache_len": max_len,
            "steps": drv.eng.steps,
            "layer_records": len(recs),
            "ops_per_step": len(profile_ops(cfg)),
            "tick_median_fused_us": round(float(np.median(w_fused)) * 1e6,
                                          1) if w_fused else 0.0,
            "tick_median_off_us": round(float(np.median(ticks_off)) * 1e6,
                                        1) if ticks_off else 0.0,
            "tick_pairs": len(ticks_off),
            "record_overhead": round(record_overhead, 4),
            "slice_overhead": round(slice_overhead, 4),
            "deterministic": deterministic,
            "coverage": cov,
            "crosscheck": {k: (round(v, 6) if isinstance(v, float) else v)
                           for k, v in crosscheck.items()},
            "full_shape": {"batch": FULL_BATCH,
                           "cache_len": FULL_CACHE_LEN},
            "flame": flame,
            "offload": offload,
            "repeats": repeats,
        }
        records.append(rec)
        if layers_dir:
            with open(os.path.join(layers_dir, f"{tag}.layers.jsonl"),
                      "w") as f:
                f.write(MPF.to_jsonl(join_prof.records, stable=True))
        top = offload[0] if offload else {"op": "?", "share": 0.0}
        emit(tag, (time.perf_counter() - t_section) * 1e6,
             f"top={top['op']}@{top['share']:.0%}"
             f"|rec_ovh={record_overhead:+.1%}"
             f"|slice_ovh={slice_overhead:+.1%}"
             f"|cov_p50={cov.get('p50', 0):.2f}"
             f"|flops_err={crosscheck['flops_rel_err']:.4f}"
             f"|det={deterministic}")
    out_path = out_path or os.environ.get("MODEL_BENCH_OUT",
                                          "BENCH_model.json")
    # write before failing: the artifact is the diagnostic
    with open(out_path, "w") as f:
        json.dump({"schema": 1,
                   "generator": "benchmarks/model_profile_bench.py",
                   "seed": SEED,
                   "device_peaks": {"flops_per_s": peaks[0],
                                    "hbm_bytes_per_s": peaks[1]},
                   "records": records}, f, indent=2)
        f.write("\n")
    emit("model_profile_json", 0.0, f"{len(records)} records -> {out_path}")
    if failures:
        raise RuntimeError("; ".join(failures))
