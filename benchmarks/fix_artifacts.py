"""Recompute model_flops / roofline fields in existing dry-run artifacts
(after a model-flops formula fix) without re-compiling anything."""
from __future__ import annotations

import json
import pathlib
import sys

from repro.launch.dryrun import model_flops
from repro.launch import hlo_stats
from repro.launch.shapes import SHAPES
from repro.models import get_config

ROOT = pathlib.Path(__file__).resolve().parents[1] / "artifacts"


def fix_dir(d: pathlib.Path) -> int:
    n = 0
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            continue
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        mf = model_flops(cfg, shape)
        coll = hlo_stats.CollectiveStats(
            bytes_by_kind=r["collectives"]["bytes_by_kind"], count_by_kind={})
        roof = hlo_stats.roofline_terms(
            {"flops": r["hlo_cost"]["flops"],
             "bytes accessed": r["hlo_cost"]["traffic_bytes"]},
            coll, r["chips"], mf)
        r["model_flops"] = mf
        r["roofline"] = roof.as_dict()
        p.write_text(json.dumps(r, indent=1))
        n += 1
    return n


if __name__ == "__main__":
    for name in sys.argv[1:] or ["dryrun_baseline", "dryrun_opt", "dryrun",
                                 "perf/iter1", "perf/iter2", "perf/iter3",
                                 "perf/iter3b", "perf/iter4", "perf/iter5",
                                 "perf/iter6", "perf/iter7"]:
        d = ROOT / name
        if d.exists():
            print(name, "->", fix_dir(d), "fixed")
