"""Chaos/goodput harness: seeded fault campaigns -> BENCH_resilience.json.

Replays the serving load harness's heavy traffic profile through the
continuous-batching engine (``repro.launch.serve.Engine``) under seeded
fault campaigns (``repro.launch.faults.FaultPlan``) with the resilience
layer armed (``repro.launch.resilience.ResilienceConfig``) and records
one row per (arch, profile, campaign):

* **zero_fault** — an uninstrumented plain engine and an uninstrumented
  engine with the resilience layer armed (finite guard on, no deadlines,
  no queue bound) are driven through the identical schedule in lockstep,
  one tick each alternately, so machine load drift cancels out of the
  paired per-tick deltas.  ``resilience_overhead`` is
  ``median(paired deltas) / median(plain ticks)`` —
  ``scripts/check_perf_regression.py`` gates it at <=5%.  The two
  engines must also produce identical token streams (the resilience-off
  equivalence contract).
* **fault campaigns** — a rate x shed-policy grid.  Each campaign
  generates a ``FaultPlan`` mixing NaN/Inf logits, step exceptions,
  latency spikes and silent cache corruption at ``fault_rate`` faulted
  steps, arms deadlines plus the campaign's admission policy, and runs
  the instrumented engine twice: the stable span streams must be
  byte-identical (``deterministic``), no request may be lost
  (``lost == 0`` — every offered request reaches a terminal state), and
  ``goodput`` (finished / offered) is gated at >=90% by the perf gate.
  ``availability`` is the fraction of engine ticks spent in the
  ``healthy`` state, ``retry_amplification`` is total attempts per
  offered request, ``shed_rate`` counts admission-control losses.

Environment overrides: ``RESILIENCE_BENCH_PROFILES`` restricts the
profile list (CI runs ``--smoke``), ``RESILIENCE_BENCH_OUT`` moves the
JSON, ``RESILIENCE_BENCH_RATES`` the fault-rate grid.

    PYTHONPATH=src python benchmarks/resilience_bench.py --smoke

This file is the committed resilience baseline: serving PRs are graded
on goodput-under-chaos, not just clean-path throughput (ROADMAP item 5).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.launch import faults as FLT, resilience as RES
from repro.launch.serve import Engine, ReplayDriver, Request
from repro.models import get_config
from repro.models import params as MP
from repro.obs import MetricsRegistry, SpanTracer, spans as SP, traffic

SEED = 0
ARCH = "qwen2-0.5b"

# mirrors serve_bench's profiles; the heavy profile saturates the slots
PROFILES: Dict[str, Dict] = {
    "smoke": dict(requests=8, slots=2, mean_interarrival=1.0,
                  prompt_lens=(4, 8), gen_lens=(4, 8)),
    "heavy": dict(requests=32, slots=4, mean_interarrival=0.5,
                  prompt_lens=(4, 8, 16), gen_lens=(8, 16, 32)),
}

RATES = (0.02, 0.05)
POLICIES = (RES.POLICY_REJECT_NEWEST, RES.POLICY_SHED_OLDEST,
            RES.POLICY_TOKEN_BUDGET)

# all five fault kinds: logit poisoning (detected same step), lockstep
# aborts, latency spikes (burn deadline ticks), silent cache corruption
# (detected only when the poison reaches the logits)
CAMPAIGN_KINDS = FLT.KINDS
SPIKE_TICKS = 2
SPIKE_US = 500

# generous per-request completion deadline (ticks); the campaigns gate
# goodput, so the deadline is a backstop against pathological queueing,
# not a latency SLO
DEADLINE_TICKS = 600
CLIENT_RETRIES = 8


def _arrivals(cfg, trace, seed: int,
              deadline_ticks: int = 0) -> List[Tuple[int, Request]]:
    rng = np.random.default_rng(seed + 1)
    return [(t.arrival_step,
             Request(t.rid,
                     rng.integers(1, cfg.vocab_size,
                                  size=t.prompt_len).astype(np.int32),
                     t.gen_len, deadline_ticks=deadline_ticks))
            for t in trace]


def _max_len(trace) -> int:
    # chaos headroom: retries replay whole requests and exception faults
    # freeze pos, so the step budget is ~4x the clean-path bound
    return 4 * (traffic.total_tokens(trace)
                + max((t.prompt_len + t.gen_len for t in trace),
                      default=0)) + 64


def _campaign_res(prof: Dict, policy: str) -> RES.ResilienceConfig:
    total = prof["requests"] * (max(prof["prompt_lens"])
                                + max(prof["gen_lens"]))
    # reject_newest bounces the newcomer back to the client (which
    # retries with backoff), so its cap can bind hard; shed_oldest
    # terminally drops committed work, so its cap only absorbs the tail
    # of the arrival burst — evictions stay a tail event, keeping the
    # degradation graceful rather than bulk loss
    if policy == RES.POLICY_SHED_OLDEST:
        cap = max(prof["requests"] - prof["slots"] - 2,
                  prof["requests"] // 2 + prof["slots"])
    else:
        cap = prof["requests"] // 2 + prof["slots"]
    return RES.ResilienceConfig(
        max_attempts=3, seed=SEED,
        deadline_ticks=DEADLINE_TICKS,
        queue_cap=cap if policy != RES.POLICY_TOKEN_BUDGET else 0,
        shed_policy=policy,
        token_budget=(total // 2
                      if policy == RES.POLICY_TOKEN_BUDGET else 0))


def _replay(cfg, params, prof: Dict, trace,
            plan: Optional[FLT.FaultPlan],
            res: Optional[RES.ResilienceConfig],
            reg: Optional[MetricsRegistry] = None,
            tr: Optional[SpanTracer] = None) -> Engine:
    eng = Engine(cfg, params, prof["slots"], _max_len(trace),
                 metrics=reg, spans=tr, faults=plan, resilience=res)
    drv = ReplayDriver(eng, _arrivals(
        cfg, trace, SEED,
        deadline_ticks=DEADLINE_TICKS if res is not None else 0),
        client_retries=CLIENT_RETRIES)
    while drv.active:
        drv.tick()
    return eng


def _lockstep_overhead(cfg, params, prof: Dict, trace
                       ) -> Tuple[Engine, Engine, float]:
    """Plain vs resilience-armed engines on the identical schedule, one
    tick each alternately; returns both engines and the median paired
    per-tick overhead of the armed side."""
    res = RES.ResilienceConfig()  # guard only: no deadlines, no bounds
    off_eng = Engine(cfg, params, prof["slots"], _max_len(trace))
    on_eng = Engine(cfg, params, prof["slots"], _max_len(trace),
                    resilience=res)
    off = ReplayDriver(off_eng, _arrivals(cfg, trace, SEED))
    on = ReplayDriver(on_eng, _arrivals(cfg, trace, SEED))
    walls: Dict[int, List[float]] = {0: [], 1: []}
    k = 0
    while off.active or on.active:
        order = (off, on) if k % 2 == 0 else (on, off)
        for drv in order:
            t0 = time.perf_counter()
            ticked = drv.tick()
            if ticked:
                walls[0 if drv is off else 1].append(
                    time.perf_counter() - t0)
        k += 1
    n = min(len(walls[0]), len(walls[1]))
    w_off = np.asarray(walls[0][:n])
    w_on = np.asarray(walls[1][:n])
    med_off = float(np.median(w_off)) if n else 0.0
    overhead = float(np.median(w_on - w_off)) / med_off if med_off else 0.0
    return off_eng, on_eng, overhead


def _tokens_by_rid(eng: Engine) -> Dict[int, list]:
    return {r.rid: list(r.out) for r in eng.done}


def run(emit, out_path: Optional[str] = None,
        profiles: Optional[List[str]] = None) -> None:
    profiles = profiles or [p.strip() for p in os.environ.get(
        "RESILIENCE_BENCH_PROFILES", "").split(",") if p.strip()] \
        or list(PROFILES)
    rates = [float(r) for r in os.environ.get(
        "RESILIENCE_BENCH_RATES", "").split(",") if r.strip()] \
        or list(RATES)
    cfg = get_config(ARCH).reduced()
    params = MP.init_params(cfg, seed=SEED)
    # compile the shared jitted step (plain + guarded) before any timing
    warm = traffic.synth_trace(SEED, 2, 0.0, (2,), (2,))
    for res in (None, RES.ResilienceConfig()):
        _replay(cfg, params, dict(slots=2), warm, None, res)
    records = []
    failures = []
    for profile in profiles:
        prof = PROFILES[profile]
        trace = traffic.synth_trace(SEED, prof["requests"],
                                    prof["mean_interarrival"],
                                    prof["prompt_lens"],
                                    prof["gen_lens"])
        offered = prof["requests"]

        # -- zero-fault lockstep: armed-but-idle must cost nothing ------
        tag = f"resilience_{ARCH}_{profile}_zero_fault"
        t_section = time.perf_counter()
        off_eng, on_eng, overhead = _lockstep_overhead(
            cfg, params, prof, trace)
        equivalent = _tokens_by_rid(off_eng) == _tokens_by_rid(on_eng)
        if not equivalent:
            failures.append(f"{tag}: armed zero-fault run diverged from "
                            f"the plain engine")
        records.append({
            "arch": ARCH, "profile": profile, "campaign": "zero_fault",
            "seed": SEED, "requests": offered,
            "steps": on_eng.steps,
            "resilience_overhead": round(overhead, 4),
            "equivalent": equivalent,
        })
        emit(tag, (time.perf_counter() - t_section) * 1e6,
             f"ovh={overhead:+.1%}|equiv={equivalent}")

        # -- fault campaigns: rate x shed policy ------------------------
        # plan horizon covers the worst-case chaotic run length
        horizon = _max_len(trace)
        for rate in rates:
            plan = FLT.FaultPlan.generate(
                SEED, horizon, rate, prof["slots"],
                kinds=CAMPAIGN_KINDS, spike_ticks=SPIKE_TICKS,
                spike_us=SPIKE_US)
            for policy in POLICIES:
                tag = (f"resilience_{ARCH}_{profile}"
                       f"_r{int(rate * 100):02d}_{policy}")
                t_section = time.perf_counter()
                res = _campaign_res(prof, policy)
                streams = []
                last = None
                for _ in range(2):
                    reg = MetricsRegistry()
                    tr = SpanTracer()
                    eng = _replay(cfg, params, prof, trace, plan, res,
                                  reg, tr)
                    streams.append(SP.to_jsonl(tr.events, stable=True))
                    last = (eng, reg, tr)
                eng, reg, tr = last
                deterministic = streams[0] == streams[1]
                if not deterministic:
                    failures.append(f"{tag}: stable span streams of two "
                                    f"same-seed chaos runs differ")
                problems = SP.validate(tr.events, slots=prof["slots"],
                                       engine_steps=eng.steps)
                if problems:
                    failures.append(f"{tag}: span invariants violated "
                                    f"(first: {problems[0]})")
                lost = offered - len(eng.done)
                if lost:
                    failures.append(f"{tag}: {lost} request(s) lost — "
                                    f"no terminal state")
                finished = sum(
                    1 for r in eng.done if r.reason == SP.FINISHED)
                by_reason = {
                    reason: int(reg.get(
                        f"serve_requests_truncated_{reason}_total").value)
                    for reason in RES.REASONS}
                shed = by_reason[RES.REASON_SHED]
                goodput = finished / offered if offered else 0.0
                ticks = sum(eng.health_ticks.values())
                avail = (eng.health_ticks.get(RES.HEALTHY, 0) / ticks
                         if ticks else 1.0)
                faulted_steps = len({s for s in range(eng.steps)
                                     if plan.at(s)})
                records.append({
                    "arch": ARCH, "profile": profile,
                    "campaign": "faults", "policy": policy,
                    "fault_rate": rate, "seed": SEED,
                    "requests": offered, "steps": eng.steps,
                    "faulted_step_frac":
                        round(faulted_steps / eng.steps, 4)
                        if eng.steps else 0.0,
                    "faults_injected": eng.faults_injected,
                    "faults_detected": eng.faults_detected,
                    "retries": eng.retries,
                    "completed": finished,
                    "truncated": by_reason,
                    "lost": lost,
                    "goodput": round(goodput, 4),
                    "availability": round(avail, 4),
                    "retry_amplification":
                        round((offered + eng.retries) / offered, 4)
                        if offered else 1.0,
                    "shed_rate": round(shed / offered, 4)
                    if offered else 0.0,
                    "deterministic": deterministic,
                })
                emit(tag, (time.perf_counter() - t_section) * 1e6,
                     f"goodput={goodput:.2f}|retries={eng.retries}"
                     f"|shed={shed}|avail={avail:.2f}"
                     f"|det={deterministic}")
    out_path = out_path or os.environ.get("RESILIENCE_BENCH_OUT",
                                          "BENCH_resilience.json")
    # write before failing: the artifact is the diagnostic
    with open(out_path, "w") as f:
        json.dump({"schema": 1,
                   "generator": "benchmarks/resilience_bench.py",
                   "seed": SEED,
                   "records": records}, f, indent=2)
        f.write("\n")
    emit("resilience_bench_json", 0.0,
         f"{len(records)} records -> {out_path}")
    if failures:
        raise RuntimeError("; ".join(failures))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="run only the short smoke profile (CI)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default BENCH_resilience.json "
                         "or $RESILIENCE_BENCH_OUT)")
    args = ap.parse_args()

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(emit, out_path=args.out,
        profiles=["smoke"] if args.smoke else None)


if __name__ == "__main__":
    main()
