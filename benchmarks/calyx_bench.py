"""Calyx-level perf tracking: estimator + simulator differential, as JSON.

Runs the design matrix (matmul, conv2d, ffnn, attention) across banking
factors {1,2,4} and share {on,off}; for each point it compiles, simulates
cycle-accurately, and records a machine-readable row — estimated cycles,
*measured* cycles, LUT/FF/DSP/BRAM, fsm states, fmax, the max abs error of
the simulated outputs against the jnp oracle, and the simulator's dynamic
counters.  The rows land in ``BENCH_calyx.json`` (override the path with
``CALYX_BENCH_OUT``) so the perf trajectory is tracked across PRs; CI
uploads the file as a build artifact.

``CALYX_BENCH_DESIGNS=matmul,conv2d`` restricts the matrix (CI runs the
two smallest designs).  Any estimate/measurement mismatch or oracle error
above 1e-4 fails the section — the benchmark doubles as the end-to-end
differential harness.

The paper's CNN is deliberately not in the matrix: its 76x56 conv plane
simulates in minutes, not seconds, and the conv2d microdesign already
exercises the identical lowering.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import frontend, pipeline

# Smallest first — CI picks the leading two via CALYX_BENCH_DESIGNS.
# Dims are divisible by every banking factor so the layout-mode
# disjointness proof succeeds at factor 4.  This matrix is the single
# source of truth: tests/test_core_sim.py imports it for the three-way
# differential suite.
DESIGNS = {
    "matmul": (lambda: frontend.Linear(8, 8, bias=False), (4, 8)),
    "conv2d": (lambda: frontend.Conv2d(2, 2, 3, 3), (2, 6, 6)),
    "ffnn": (frontend.paper_ffnn, (1, 64)),
    "attention": (lambda: frontend.MultiheadAttention(8, 2), (4, 8)),
}

FACTORS = (1, 2, 4)
ORACLE_TOL = 1e-4


def run(emit, out_path: str | None = None) -> None:
    names = os.environ.get("CALYX_BENCH_DESIGNS", "")
    selected = [n.strip() for n in names.split(",") if n.strip()] \
        or list(DESIGNS)
    rng = np.random.default_rng(0)
    records = []
    failures = []
    for name in selected:
        builder, shape = DESIGNS[name]
        x = rng.normal(size=shape).astype(np.float32)
        for factor in FACTORS:
            for share in (True, False):
                t0 = time.perf_counter()
                try:
                    d = pipeline.compile_model(builder(), [shape],
                                               factor=factor, share=share)
                    outs, stats = d.simulate({"arg0": x})
                except Exception as exc:   # keep filling the matrix
                    failures.append(
                        f"{name} f{factor} share={share}: {exc}")
                    records.append({"design": name, "banks": factor,
                                    "share": share, "error": str(exc)})
                    emit(f"calyx_{name}_f{factor}_"
                         f"{'shared' if share else 'unshared'}",
                         (time.perf_counter() - t0) * 1e6,
                         f"ERROR {type(exc).__name__}")
                    continue
                wall_us = (time.perf_counter() - t0) * 1e6
                oracle = d.run_oracle({"arg0": x})
                err = max(float(np.max(np.abs(s - o)))
                          for s, o in zip(outs, oracle))
                est = d.estimate
                rec = {
                    "design": name,
                    "banks": factor,
                    "share": share,
                    "cycles": est.cycles,
                    "sim_cycles": stats.cycles,
                    "cycles_match": stats.cycles == est.cycles,
                    "oracle_max_abs_err": err,
                    "LUT": est.resources["LUT"],
                    "FF": est.resources["FF"],
                    "DSP": est.resources["DSP"],
                    "BRAM": est.resources["BRAM"],
                    "fsm_states": est.fsm_states,
                    "fmax_mhz": est.fmax_mhz,
                    "wall_us": est.wall_us,
                    "cells": len(d.component.cells),
                    "groups": len(d.component.groups),
                    "sim": stats.as_dict(),
                }
                records.append(rec)
                tag = "shared" if share else "unshared"
                emit(f"calyx_{name}_f{factor}_{tag}", wall_us,
                     f"cycles={est.cycles}|sim={stats.cycles}|err={err:.1e}")
                if stats.cycles != est.cycles:
                    failures.append(
                        f"{name} f{factor} share={share}: simulated "
                        f"{stats.cycles} cycles but estimated {est.cycles}")
                if err > ORACLE_TOL:
                    failures.append(
                        f"{name} f{factor} share={share}: oracle error "
                        f"{err:.2e} exceeds {ORACLE_TOL}")
    # Write the JSON before failing: on a divergence the artifact with the
    # full per-design matrix (cycles_match=false rows) is the diagnostic.
    out_path = out_path or os.environ.get("CALYX_BENCH_OUT",
                                          "BENCH_calyx.json")
    with open(out_path, "w") as f:
        json.dump({"schema": 1,
                   "generator": "benchmarks/calyx_bench.py",
                   "records": records}, f, indent=2)
        f.write("\n")
    emit("calyx_bench_json", 0.0, f"{len(records)} records -> {out_path}")
    if failures:
        raise RuntimeError("; ".join(failures))
