"""Calyx-level perf tracking: the four-way differential matrix, as JSON.

Runs the design matrix (matmul, conv2d, ffnn, attention) across banking
factors {1,2,4}, share {on,off}, and the scheduling-layer ablation
opt_level {0,2}; for each point it compiles, simulates the Calyx
component cycle-accurately, lowers to the RTL netlist, executes *that*
with the RTL-level simulator, and records a machine-readable row —
estimated cycles, Calyx-measured cycles, RTL-measured cycles, resources,
fsm states, fmax, banking efficiency, the pipelined loops' initiation
intervals, netlist size (FSMs/states/muxes/units/banks), emitted
SystemVerilog module/LoC counts, the max abs error of the simulated
outputs against the jnp oracle, and the simulators' dynamic counters.
Since schema 4 each row also carries the compile wall-clock
(``compile_us``, compile_model + RTL lowering) and the slice of it spent
in the stage-boundary verifier (``verify_us``, summed over the
per-boundary ``DiagnosticReport.wall_us`` stamps) plus the finding count
— any finding at all fails the section, and
``scripts/check_perf_regression.py`` gates the aggregate verifier
overhead at <15% of compile time (measured: ~13-14% across the full
matrix for the five-boundary suite; the compile window is timed with
the garbage collector paused so collector pauses landing inside a
verify boundary cannot swing the ratio).

Since schema 5 each row also runs the observability layer
(``repro.core.trace`` / ``repro.core.profiler``): both simulators run a
second time with tracing on and the profiled netlist's synthesized
counter bank active, and the row records ``counters_match`` (the full
differential — Calyx-sim stats == RTL-sim stats == both trace
aggregates == hardware counter values == analytic attribution, exact
for if-free designs), the per-cause ``stalls`` breakdown, per-port and
per-unit ``occupancy``, the previously dropped dynamic counters
(``fu_grants``/``serialized_arms``/``broadcast_reads``) as first-class
columns, and the tracing-off vs tracing-on simulator wall clocks
(``sim_wall_us``/``trace_wall_us``) so the perf gate can assert the
disabled trace hook stays within its overhead budget
(``--sim-wall-overhead``).  Any differential mismatch, or a lint
violation in the profiled SystemVerilog, fails the section.  The rows
land in ``BENCH_calyx.json``
(override the path with ``CALYX_BENCH_OUT``) so the perf *and*
netlist-size trajectory is tracked across PRs; CI uploads the file as a
build artifact and gates on it (``scripts/check_perf_regression.py``
fails any point whose cycles regress >2% over the committed baseline).

A ``calyx_opt_geomean_speedup`` summary line reports the geometric-mean
opt_level 0 -> 2 cycle reduction across the matrix.

``CALYX_BENCH_DESIGNS=matmul,conv2d`` restricts the matrix (CI runs the
two smallest designs).  Any estimate/measurement mismatch at either
level, any RTL-vs-Calyx output divergence (bit-exact), any oracle error
above 1e-4, or any Verilog lint violation fails the section — the
benchmark doubles as the end-to-end differential harness.

The paper's CNN is deliberately not in the matrix: its 76x56 conv plane
simulates in minutes, not seconds, and the conv2d microdesign already
exercises the identical lowering.
"""
from __future__ import annotations

import gc
import json
import math
import os
import time
import warnings

import numpy as np

from repro.core import estimator, frontend, pipeline, profiler, trace, \
    verilog

# Smallest first — CI picks the leading two via CALYX_BENCH_DESIGNS.
# Dims are divisible by every banking factor so the layout-mode
# disjointness proof succeeds at factor 4.  This matrix is the single
# source of truth: tests/test_core_sim.py and
# tests/test_core_scheduling.py import it for the differential suites.
DESIGNS = {
    "matmul": (lambda: frontend.Linear(8, 8, bias=False), (4, 8)),
    "conv2d": (lambda: frontend.Conv2d(2, 2, 3, 3), (2, 6, 6)),
    "ffnn": (frontend.paper_ffnn, (1, 64)),
    "attention": (lambda: frontend.MultiheadAttention(8, 2), (4, 8)),
}

FACTORS = (1, 2, 4)
OPT_LEVELS = (0, 2)          # the scheduling-layer ablation
ORACLE_TOL = 1e-4


def run(emit, out_path: str | None = None) -> None:
    names = os.environ.get("CALYX_BENCH_DESIGNS", "")
    selected = [n.strip() for n in names.split(",") if n.strip()] \
        or list(DESIGNS)
    rng = np.random.default_rng(0)
    records = []
    failures = []
    # cycles by (design, factor, share) per opt level, for the geomean
    by_point: dict = {}
    for name in selected:
        builder, shape = DESIGNS[name]
        x = rng.normal(size=shape).astype(np.float32)
        for factor in FACTORS:
            for share in (True, False):
                for opt in OPT_LEVELS:
                    # keep collector pauses out of the compile/verify
                    # timing window: a gen-2 collection landing inside a
                    # verify boundary would swing the overhead ratio the
                    # regression gate checks
                    gc_was_on = gc.isenabled()
                    gc.collect()
                    gc.disable()
                    t0 = time.perf_counter()
                    try:
                        with warnings.catch_warnings():
                            warnings.simplefilter(
                                "ignore",
                                estimator.BankingEfficiencyWarning)
                            d = pipeline.compile_model(
                                builder(), [shape], factor=factor,
                                share=share, opt_level=opt)
                            d.to_rtl()   # lower (and verify) the netlist
                        compile_us = (time.perf_counter() - t0) * 1e6
                        # the profiled lowering below appends a sixth
                        # verify report outside the compile window; keep
                        # the overhead ratio over the same five stages
                        compile_reports = list(d.verify_reports)
                        # tracing-off vs tracing-on Calyx-sim wall clock:
                        # still gc-paused so a collection inside either
                        # window can't fake a trace-hook overhead
                        ts = time.perf_counter()
                        outs, stats = d.simulate({"arg0": x})
                        sim_wall_us = (time.perf_counter() - ts) * 1e6
                        tr_sim = trace.Tracer()
                        ts = time.perf_counter()
                        _, stats_tr = d.simulate({"arg0": x},
                                                 tracer=tr_sim)
                        trace_wall_us = (time.perf_counter() - ts) * 1e6
                        if gc_was_on:
                            gc.enable()
                        rtl_outs, rtl_stats = d.simulate_rtl({"arg0": x})
                        tr_rtl = trace.Tracer()
                        _, rtl_tr_stats = d.simulate_rtl(
                            {"arg0": x}, tracer=tr_rtl, profile=True)
                        sv_text = d.emit_verilog()
                        sv_text_prof = d.emit_verilog(profile=True)
                    except Exception as exc:   # keep filling the matrix
                        if gc_was_on:
                            gc.enable()
                        failures.append(
                            f"{name} f{factor} share={share} o{opt}: {exc}")
                        records.append({"design": name, "banks": factor,
                                        "share": share, "opt_level": opt,
                                        "error": str(exc)})
                        emit(f"calyx_{name}_f{factor}_"
                             f"{'shared' if share else 'unshared'}_o{opt}",
                             (time.perf_counter() - t0) * 1e6,
                             f"ERROR {type(exc).__name__}")
                        continue
                    wall_us = (time.perf_counter() - t0) * 1e6
                    oracle = d.run_oracle({"arg0": x})
                    err = max(float(np.max(np.abs(s - o)))
                              for s, o in zip(outs, oracle))
                    rtl_bitexact = all(np.array_equal(a, b)
                                       for a, b in zip(rtl_outs, outs))
                    lint_errors = verilog.lint(sv_text)
                    prof_lint_errors = verilog.lint(sv_text_prof)
                    att = estimator.attribute(d.component)
                    mism = profiler.counter_mismatches(
                        stats_tr, rtl_tr_stats, tr_sim.events,
                        tr_rtl.events, attribution=att,
                        hw_counters=rtl_tr_stats.counters)
                    stl = profiler.stall_breakdown(tr_rtl.events)
                    occ = profiler.occupancy(tr_rtl.events,
                                             rtl_tr_stats.cycles)
                    est = d.estimate
                    netlist = d.to_rtl().stats()
                    verify_us = sum(r.wall_us for r in compile_reports)
                    verify_findings = sum(len(r) for r in d.verify_reports)
                    pipelined = d.component.meta.get("pipelined") or []
                    rec = {
                        "design": name,
                        "banks": factor,
                        "share": share,
                        "opt_level": opt,
                        "cycles": est.cycles,
                        "sim_cycles": stats.cycles,
                        "rtl_cycles": rtl_stats.cycles,
                        "cycles_match": stats.cycles == est.cycles
                                        == rtl_stats.cycles,
                        "rtl_bitexact": rtl_bitexact,
                        "oracle_max_abs_err": err,
                        "banking_efficiency": est.banking_efficiency,
                        "ii": max((p["ii"] for p in pipelined), default=0),
                        "pipelined_loops": len(pipelined),
                        "LUT": est.resources["LUT"],
                        "FF": est.resources["FF"],
                        "DSP": est.resources["DSP"],
                        "BRAM": est.resources["BRAM"],
                        "fsm_states": est.fsm_states,
                        "fmax_mhz": est.fmax_mhz,
                        "wall_us": est.wall_us,
                        "cells": len(d.component.cells),
                        "groups": len(d.component.groups),
                        "netlist": netlist,
                        "sv_modules": sum(
                            1 for ln in sv_text.splitlines()
                            if ln.startswith("module ")),
                        "sv_loc": len(sv_text.splitlines()),
                        "sv_loc_profiled": len(sv_text_prof.splitlines()),
                        "sv_lint_errors": len(lint_errors),
                        "sv_lint_errors_profiled": len(prof_lint_errors),
                        "counters_match": not mism,
                        "attribution_exact": att.exact,
                        "trace_events": len(tr_rtl.events),
                        "sim_wall_us": round(sim_wall_us, 1),
                        "trace_wall_us": round(trace_wall_us, 1),
                        "fu_grants": sum(stats.fu_grants.values()),
                        "serialized_arms": stats.serialized_arms,
                        "broadcast_reads": stats.broadcast_reads,
                        "stalls": stl,
                        "occupancy": occ,
                        "compile_us": round(compile_us, 1),
                        "verify_us": round(verify_us, 1),
                        "verify_stages": len(compile_reports),
                        "verify_findings": verify_findings,
                        "sim": stats.as_dict(),
                        "rtl_sim": rtl_stats.as_dict(),
                    }
                    records.append(rec)
                    by_point.setdefault((name, factor, share), {})[opt] = \
                        est.cycles
                    tag = "shared" if share else "unshared"
                    emit(f"calyx_{name}_f{factor}_{tag}_o{opt}", wall_us,
                         f"cycles={est.cycles}|sim={stats.cycles}"
                         f"|rtl={rtl_stats.cycles}|ii={rec['ii']}"
                         f"|err={err:.1e}")
                    if stats.cycles != est.cycles:
                        failures.append(
                            f"{name} f{factor} share={share} o{opt}: "
                            f"simulated {stats.cycles} cycles but "
                            f"estimated {est.cycles}")
                    if rtl_stats.cycles != est.cycles:
                        failures.append(
                            f"{name} f{factor} share={share} o{opt}: RTL "
                            f"measured {rtl_stats.cycles} cycles but "
                            f"estimated {est.cycles}")
                    if not rtl_bitexact:
                        failures.append(
                            f"{name} f{factor} share={share} o{opt}: RTL "
                            f"outputs diverge bit-wise from the Calyx "
                            f"simulation")
                    if lint_errors:
                        failures.append(
                            f"{name} f{factor} share={share} o{opt}: "
                            f"emitted Verilog has {len(lint_errors)} lint "
                            f"violations (first: {lint_errors[0]})")
                    if prof_lint_errors:
                        failures.append(
                            f"{name} f{factor} share={share} o{opt}: "
                            f"profiled Verilog has "
                            f"{len(prof_lint_errors)} lint violations "
                            f"(first: {prof_lint_errors[0]})")
                    if mism:
                        failures.append(
                            f"{name} f{factor} share={share} o{opt}: "
                            f"observability differential mismatch "
                            f"(first: {mism[0]})")
                    if verify_findings:
                        first = next(diag for r in d.verify_reports
                                     for diag in r)
                        failures.append(
                            f"{name} f{factor} share={share} o{opt}: "
                            f"verifier reported {verify_findings} "
                            f"finding(s) (first: {first.format()})")
                    if err > ORACLE_TOL:
                        failures.append(
                            f"{name} f{factor} share={share} o{opt}: "
                            f"oracle error {err:.2e} exceeds {ORACLE_TOL}")
    # opt_level ablation summary: geomean 0 -> 2 speedup over the matrix
    ratios = [c[0] / c[2] for c in by_point.values()
              if 0 in c and 2 in c and c[2] > 0]
    geomean = (math.exp(sum(math.log(r) for r in ratios) / len(ratios))
               if ratios else 0.0)
    emit("calyx_opt_geomean_speedup", 0.0,
         f"{geomean:.2f}x over {len(ratios)} points (opt 0 -> 2)")
    # Write the JSON before failing: on a divergence the artifact with the
    # full per-design matrix (cycles_match=false rows) is the diagnostic.
    out_path = out_path or os.environ.get("CALYX_BENCH_OUT",
                                          "BENCH_calyx.json")
    with open(out_path, "w") as f:
        json.dump({"schema": 5,
                   "generator": "benchmarks/calyx_bench.py",
                   "opt_geomean_speedup": round(geomean, 3),
                   "records": records}, f, indent=2)
        f.write("\n")
    emit("calyx_bench_json", 0.0, f"{len(records)} records -> {out_path}")
    if failures:
        raise RuntimeError("; ".join(failures))
