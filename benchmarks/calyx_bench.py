"""Calyx-level perf tracking: the four-way differential matrix, as JSON.

Runs the design matrix (matmul, conv2d, ffnn, attention) across banking
factors {1,2,4} and share {on,off}; for each point it compiles, simulates
the Calyx component cycle-accurately, lowers to the RTL netlist, executes
*that* with the RTL-level simulator, and records a machine-readable row —
estimated cycles, Calyx-measured cycles, RTL-measured cycles, resources,
fsm states, fmax, netlist size (FSMs/states/muxes/units/banks), emitted
SystemVerilog module/LoC counts, the max abs error of the simulated
outputs against the jnp oracle, and the simulators' dynamic counters.
The rows land in ``BENCH_calyx.json`` (override the path with
``CALYX_BENCH_OUT``) so the perf *and* netlist-size trajectory is tracked
across PRs; CI uploads the file as a build artifact.

``CALYX_BENCH_DESIGNS=matmul,conv2d`` restricts the matrix (CI runs the
two smallest designs).  Any estimate/measurement mismatch at either
level, any RTL-vs-Calyx output divergence (bit-exact), any oracle error
above 1e-4, or any Verilog lint violation fails the section — the
benchmark doubles as the end-to-end differential harness.

The paper's CNN is deliberately not in the matrix: its 76x56 conv plane
simulates in minutes, not seconds, and the conv2d microdesign already
exercises the identical lowering.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import frontend, pipeline, verilog

# Smallest first — CI picks the leading two via CALYX_BENCH_DESIGNS.
# Dims are divisible by every banking factor so the layout-mode
# disjointness proof succeeds at factor 4.  This matrix is the single
# source of truth: tests/test_core_sim.py imports it for the three-way
# differential suite.
DESIGNS = {
    "matmul": (lambda: frontend.Linear(8, 8, bias=False), (4, 8)),
    "conv2d": (lambda: frontend.Conv2d(2, 2, 3, 3), (2, 6, 6)),
    "ffnn": (frontend.paper_ffnn, (1, 64)),
    "attention": (lambda: frontend.MultiheadAttention(8, 2), (4, 8)),
}

FACTORS = (1, 2, 4)
ORACLE_TOL = 1e-4


def run(emit, out_path: str | None = None) -> None:
    names = os.environ.get("CALYX_BENCH_DESIGNS", "")
    selected = [n.strip() for n in names.split(",") if n.strip()] \
        or list(DESIGNS)
    rng = np.random.default_rng(0)
    records = []
    failures = []
    for name in selected:
        builder, shape = DESIGNS[name]
        x = rng.normal(size=shape).astype(np.float32)
        for factor in FACTORS:
            for share in (True, False):
                t0 = time.perf_counter()
                try:
                    d = pipeline.compile_model(builder(), [shape],
                                               factor=factor, share=share)
                    outs, stats = d.simulate({"arg0": x})
                    rtl_outs, rtl_stats = d.simulate_rtl({"arg0": x})
                    sv_text = d.emit_verilog()
                except Exception as exc:   # keep filling the matrix
                    failures.append(
                        f"{name} f{factor} share={share}: {exc}")
                    records.append({"design": name, "banks": factor,
                                    "share": share, "error": str(exc)})
                    emit(f"calyx_{name}_f{factor}_"
                         f"{'shared' if share else 'unshared'}",
                         (time.perf_counter() - t0) * 1e6,
                         f"ERROR {type(exc).__name__}")
                    continue
                wall_us = (time.perf_counter() - t0) * 1e6
                oracle = d.run_oracle({"arg0": x})
                err = max(float(np.max(np.abs(s - o)))
                          for s, o in zip(outs, oracle))
                rtl_bitexact = all(np.array_equal(a, b)
                                   for a, b in zip(rtl_outs, outs))
                lint_errors = verilog.lint(sv_text)
                est = d.estimate
                netlist = d.to_rtl().stats()
                rec = {
                    "design": name,
                    "banks": factor,
                    "share": share,
                    "cycles": est.cycles,
                    "sim_cycles": stats.cycles,
                    "rtl_cycles": rtl_stats.cycles,
                    "cycles_match": stats.cycles == est.cycles
                                    == rtl_stats.cycles,
                    "rtl_bitexact": rtl_bitexact,
                    "oracle_max_abs_err": err,
                    "LUT": est.resources["LUT"],
                    "FF": est.resources["FF"],
                    "DSP": est.resources["DSP"],
                    "BRAM": est.resources["BRAM"],
                    "fsm_states": est.fsm_states,
                    "fmax_mhz": est.fmax_mhz,
                    "wall_us": est.wall_us,
                    "cells": len(d.component.cells),
                    "groups": len(d.component.groups),
                    "netlist": netlist,
                    "sv_modules": sum(
                        1 for ln in sv_text.splitlines()
                        if ln.startswith("module ")),
                    "sv_loc": len(sv_text.splitlines()),
                    "sv_lint_errors": len(lint_errors),
                    "sim": stats.as_dict(),
                    "rtl_sim": rtl_stats.as_dict(),
                }
                records.append(rec)
                tag = "shared" if share else "unshared"
                emit(f"calyx_{name}_f{factor}_{tag}", wall_us,
                     f"cycles={est.cycles}|sim={stats.cycles}"
                     f"|rtl={rtl_stats.cycles}|err={err:.1e}")
                if stats.cycles != est.cycles:
                    failures.append(
                        f"{name} f{factor} share={share}: simulated "
                        f"{stats.cycles} cycles but estimated {est.cycles}")
                if rtl_stats.cycles != est.cycles:
                    failures.append(
                        f"{name} f{factor} share={share}: RTL measured "
                        f"{rtl_stats.cycles} cycles but estimated "
                        f"{est.cycles}")
                if not rtl_bitexact:
                    failures.append(
                        f"{name} f{factor} share={share}: RTL outputs "
                        f"diverge bit-wise from the Calyx simulation")
                if lint_errors:
                    failures.append(
                        f"{name} f{factor} share={share}: emitted Verilog "
                        f"has {len(lint_errors)} lint violations "
                        f"(first: {lint_errors[0]})")
                if err > ORACLE_TOL:
                    failures.append(
                        f"{name} f{factor} share={share}: oracle error "
                        f"{err:.2e} exceeds {ORACLE_TOL}")
    # Write the JSON before failing: on a divergence the artifact with the
    # full per-design matrix (cycles_match=false rows) is the diagnostic.
    out_path = out_path or os.environ.get("CALYX_BENCH_OUT",
                                          "BENCH_calyx.json")
    with open(out_path, "w") as f:
        json.dump({"schema": 2,
                   "generator": "benchmarks/calyx_bench.py",
                   "records": records}, f, indent=2)
        f.write("\n")
    emit("calyx_bench_json", 0.0, f"{len(records)} records -> {out_path}")
    if failures:
        raise RuntimeError("; ".join(failures))
