"""Serving-side load harness: seeded heavy-traffic replay -> BENCH_serve.json.

Replays a synthetic traffic trace (Poisson arrivals in engine-step time,
mixed prompt/generation lengths — ``repro.obs.traffic``) through the
instrumented continuous-batching engine (``repro.launch.serve.Engine``)
for at least two architectures spanning two model families — a dense
transformer and a non-transformer SSM — and records one row per
(arch, profile):

* **latency**: TTFT p50/p99 and steady-state per-token decode latency
  p50/p99, both as the metrics registry's bucket-interpolated quantiles
  (the values a live exporter would report) and as exact numpy quantiles
  over the raw span stream;
* **throughput**: generated tokens/sec over the *uninstrumented* wall
  clock, plus engine steps and slot utilization from the span stream;
* **overhead**: tracing-off vs tracing-on wall clock.  The gated
  ``trace_overhead`` drives an uninstrumented and an instrumented engine
  through the identical schedule *in lockstep* — one tick (admit+step)
  on each engine alternately, alternating which side goes first — so
  every off/on wall-clock pair is taken milliseconds apart and machine
  load drift cancels out of the pairwise delta.  (Back-to-back full
  runs are seconds apart; total-wall deltas over such windows swing
  +-15% on shared machines.)  ``decode.make_serve_step`` caches the
  jitted step per config, so both sides share one compilation.  The
  estimate is ``median(paired deltas) / median(off ticks)`` pooled
  across ``SERVE_BENCH_REPEATS`` lockstep runs; the min-total-wall
  ratio is recorded alongside as ``trace_overhead_total``
  (informational).  ``scripts/check_perf_regression.py`` gates
  ``trace_overhead`` at <=5%;
* **determinism**: two traced runs of the same seed must serialize
  byte-identically in the span exporter's stable mode — recorded as
  ``deterministic`` and enforced here (a mismatch fails the section), as
  does any span-lifecycle violation (``spans.validate``).

Environment overrides: ``SERVE_BENCH_ARCHS`` / ``SERVE_BENCH_PROFILES``
restrict the matrix (CI runs the smallest arch on the short ``smoke``
profile), ``SERVE_BENCH_OUT`` moves the JSON, ``SERVE_BENCH_REPEATS``
sets the paired-run count, and ``SERVE_BENCH_SPANS_DIR`` additionally
writes the stable span JSONL + Prometheus text per point as artifacts.

This file is the committed baseline every serving/streaming PR (ROADMAP
items 2 and 5 — continuous-batching scheduler, prefix cache) is graded
against: the scheduler lands on top of a measured queue-latency baseline
rather than vibes.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.launch.serve import Engine, ReplayDriver, Request
from repro.models import get_config
from repro.models import params as MP
from repro.obs import MetricsRegistry, SpanTracer, spans as SP, traffic

SEED = 0

# smallest arch first — CI picks it via SERVE_BENCH_ARCHS; rwkv6 covers
# the non-transformer (ssm) family with its O(1) recurrent cache
ARCHS = ("qwen2-0.5b", "rwkv6-7b")

# ``smoke`` is the CI profile (short trace, small slot count); ``heavy``
# saturates the slots with Poisson arrivals and mixed lengths
PROFILES: Dict[str, Dict] = {
    "smoke": dict(requests=8, slots=2, mean_interarrival=1.0,
                  prompt_lens=(4, 8), gen_lens=(4, 8)),
    "heavy": dict(requests=32, slots=4, mean_interarrival=0.5,
                  prompt_lens=(4, 8, 16), gen_lens=(8, 16, 32)),
}


def _build_arrivals(cfg, trace, seed: int) -> List[Tuple[int, Request]]:
    """Fresh Request objects (they are mutated by the engine) with
    seed-deterministic prompt token content."""
    rng = np.random.default_rng(seed + 1)
    return [(t.arrival_step,
             Request(t.rid,
                     rng.integers(1, cfg.vocab_size,
                                  size=t.prompt_len).astype(np.int32),
                     t.gen_len))
            for t in trace]


def _max_len(trace) -> int:
    return traffic.total_tokens(trace) \
        + max((t.prompt_len + t.gen_len for t in trace), default=0) + 8


def _make_driver(cfg, params, prof: Dict, trace, seed: int,
                 metrics: Optional[MetricsRegistry] = None,
                 spans: Optional[SpanTracer] = None) -> ReplayDriver:
    eng = Engine(cfg, params, prof["slots"], _max_len(trace),
                 metrics=metrics, spans=spans)
    return ReplayDriver(eng, _build_arrivals(cfg, trace, seed))


def _lockstep_replay(cfg, params, prof: Dict, trace, seed: int,
                     reg: MetricsRegistry, tr: SpanTracer
                     ) -> Tuple[Engine, Engine,
                                List[float], List[float]]:
    """Drive an uninstrumented and an instrumented engine through the
    identical arrival schedule one tick at a time, alternating which
    side runs first; returns both drained engines and the per-tick wall
    seconds of every paired tick (every engine step syncs on its
    outputs, so the deltas are true post-device measurements)."""
    off = _make_driver(cfg, params, prof, trace, seed)
    on = _make_driver(cfg, params, prof, trace, seed,
                      metrics=reg, spans=tr)
    walls_off: List[float] = []
    walls_on: List[float] = []
    k = 0
    while off.active or on.active:
        first, second = (off, on) if k % 2 == 0 else (on, off)
        for drv in (first, second):
            t0 = time.perf_counter()
            ticked = drv.tick()
            wall = time.perf_counter() - t0
            if ticked:
                (walls_off if drv is off else walls_on).append(wall)
        k += 1
    n = min(len(walls_off), len(walls_on))
    return off.eng, on.eng, walls_off[:n], walls_on[:n]


def _quantiles(values: List[float]) -> Dict[str, float]:
    if not values:
        return {"p50": 0.0, "p99": 0.0}
    arr = np.asarray(values, np.float64)
    return {"p50": round(float(np.quantile(arr, 0.5)), 1),
            "p99": round(float(np.quantile(arr, 0.99)), 1)}


def run(emit, out_path: Optional[str] = None) -> None:
    archs = [a.strip() for a in
             os.environ.get("SERVE_BENCH_ARCHS", "").split(",")
             if a.strip()] or list(ARCHS)
    profiles = [p.strip() for p in
                os.environ.get("SERVE_BENCH_PROFILES", "").split(",")
                if p.strip()] or list(PROFILES)
    repeats = max(1, int(os.environ.get("SERVE_BENCH_REPEATS", "3")))
    spans_dir = os.environ.get("SERVE_BENCH_SPANS_DIR", "")
    if spans_dir:
        os.makedirs(spans_dir, exist_ok=True)
    records = []
    failures = []
    for arch in archs:
        cfg = get_config(arch).reduced()
        params = MP.init_params(cfg, seed=SEED)
        # one tiny replay to compile the shared jitted step before any
        # timed run — both timed sides then see the same warm cache
        warm = traffic.synth_trace(SEED, 2, 0.0, (2,), (2,))
        drv = _make_driver(cfg, params, dict(slots=2), warm, SEED)
        while drv.active:
            drv.tick()
        for profile in profiles:
            prof = PROFILES[profile]
            trace = traffic.synth_trace(SEED, prof["requests"],
                                        prof["mean_interarrival"],
                                        prof["prompt_lens"],
                                        prof["gen_lens"])
            tag = f"serve_{arch}_{profile}"
            t_section = time.perf_counter()
            # lockstep repeats; pooled paired per-tick walls give the
            # noise-robust overhead estimate, min total wall the
            # throughput one
            wall_off = wall_on = float("inf")
            ticks_off: List[float] = []
            ticks_on: List[float] = []
            last: Optional[Tuple[Engine, MetricsRegistry, SpanTracer]] = None
            stable_streams = []
            for _ in range(max(repeats, 2)):
                reg = MetricsRegistry()
                tr = SpanTracer()
                eng_off, eng_on, w_off, w_on = _lockstep_replay(
                    cfg, params, prof, trace, SEED, reg, tr)
                ticks_off.extend(w_off)
                ticks_on.extend(w_on)
                wall_off = min(wall_off, sum(w_off))
                wall_on = min(wall_on, sum(w_on))
                last = (eng_on, reg, tr)
                if len(stable_streams) < 2:
                    stable_streams.append(SP.to_jsonl(tr.events,
                                                      stable=True))
                if eng_off.steps != eng_on.steps:
                    failures.append(
                        f"{tag}: instrumented run took {eng_on.steps} "
                        f"steps, uninstrumented {eng_off.steps}")
            assert last is not None
            eng, reg, tr = last
            deterministic = stable_streams[0] == stable_streams[1]
            if not deterministic:
                failures.append(f"{tag}: stable span streams of two "
                                f"same-seed runs differ")
            problems = SP.validate(tr.events, slots=prof["slots"],
                                   engine_steps=eng.steps)
            if problems:
                failures.append(f"{tag}: span invariants violated "
                                f"(first: {problems[0]})")
            summaries = SP.summarize(tr.events)
            finished = [s for s in summaries.values()
                        if s.reason == SP.FINISHED]
            truncated = [s for s in summaries.values()
                         if s.reason.startswith(SP.TRUNCATED_PREFIX)]
            if len(finished) != prof["requests"]:
                failures.append(
                    f"{tag}: {len(finished)}/{prof['requests']} finished "
                    f"({len(truncated)} truncated) — size max_len up")
            ttfts = [float(s.ttft_us) for s in finished if s.ttft_us >= 0]
            dtoks = [s.decode_us_per_token for s in finished
                     if s.tokens >= 2]
            gen_tokens = int(reg.get("serve_tokens_generated_total").value)
            med_off = float(np.median(ticks_off)) if ticks_off else 0.0
            deltas = np.asarray(ticks_on) - np.asarray(ticks_off)
            overhead = float(np.median(deltas)) / med_off \
                if med_off else 0.0
            overhead_total = (wall_on - wall_off) / wall_off \
                if wall_off else 0.0
            ttft_h = reg.get("serve_ttft_us")
            dtok_h = reg.get("serve_decode_token_us")
            rec = {
                "arch": arch,
                "family": cfg.family,
                "profile": profile,
                "seed": SEED,
                "requests": prof["requests"],
                "slots": prof["slots"],
                "steps": eng.steps,
                "completed": len(finished),
                "truncated": len(truncated),
                "tokens_generated": gen_tokens,
                "tokens_prefill":
                    int(reg.get("serve_tokens_prefill_total").value),
                "wall_off_us": round(wall_off * 1e6, 1),
                "wall_on_us": round(wall_on * 1e6, 1),
                "tick_median_off_us": round(med_off * 1e6, 1),
                "tick_median_delta_us":
                    round(float(np.median(deltas)) * 1e6, 2),
                "tick_pairs": len(ticks_off),
                "trace_overhead": round(overhead, 4),
                "trace_overhead_total": round(overhead_total, 4),
                "tokens_per_sec": round(gen_tokens / wall_off, 1),
                "ttft_us": {"p50": round(ttft_h.quantile(0.5), 1),
                            "p99": round(ttft_h.quantile(0.99), 1),
                            **{f"{k}_exact": v
                               for k, v in _quantiles(ttfts).items()}},
                "decode_tok_us": {"p50": round(dtok_h.quantile(0.5), 1),
                                  "p99": round(dtok_h.quantile(0.99), 1),
                                  **{f"{k}_exact": v
                                     for k, v in _quantiles(dtoks).items()}},
                "slot_utilization":
                    round(SP.slot_utilization(tr.events, prof["slots"]), 4),
                "span_events": len(tr.events),
                "deterministic": deterministic,
                "repeats": max(repeats, 2),
            }
            records.append(rec)
            if spans_dir:
                base = os.path.join(spans_dir, f"{tag}")
                with open(base + ".spans.jsonl", "w") as f:
                    f.write(SP.to_jsonl(tr.events, stable=True))
                with open(base + ".prom", "w") as f:
                    f.write(reg.to_prometheus())
            emit(tag, (time.perf_counter() - t_section) * 1e6,
                 f"ttft_p99={rec['ttft_us']['p99']:.0f}us"
                 f"|tok/s={rec['tokens_per_sec']:.0f}"
                 f"|util={rec['slot_utilization']:.2f}"
                 f"|ovh={overhead:+.1%}"
                 f"|det={deterministic}")
    out_path = out_path or os.environ.get("SERVE_BENCH_OUT",
                                          "BENCH_serve.json")
    # write before failing: the artifact is the diagnostic
    with open(out_path, "w") as f:
        json.dump({"schema": 1,
                   "generator": "benchmarks/serve_bench.py",
                   "seed": SEED,
                   "records": records}, f, indent=2)
        f.write("\n")
    emit("serve_bench_json", 0.0, f"{len(records)} records -> {out_path}")
    if failures:
        raise RuntimeError("; ".join(failures))
