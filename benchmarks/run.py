"""Benchmark harness — one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV per line.  Sections:
  paper_tables      Fig 2 / Table 1 / Fig 3 / Table 2 reproduction
  banking_ablation  layout-vs-branchy, restructuring, port model, MoE HLO
  calyx_bench       simulator/estimator differential -> BENCH_calyx.json
  serve_bench       serving load harness -> BENCH_serve.json
  resilience_bench  chaos/goodput harness -> BENCH_resilience.json
  kernel_bench      Pallas kernel microbenches (interpret mode)
  model_profile_bench  per-operator decode profiles -> BENCH_model.json
  roofline_report   offload ranking from BENCH_model.json (+ dry-run cells)
"""
from __future__ import annotations

import sys
import time
import traceback


def _emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def main() -> None:
    sections = sys.argv[1:] or ["paper_tables", "banking_ablation",
                                "calyx_bench", "serve_bench",
                                "resilience_bench", "kernel_bench",
                                "model_profile_bench", "roofline_report"]
    t0 = time.time()
    failures = []
    for section in sections:
        print(f"# --- {section} ---", flush=True)
        try:
            if section == "paper_tables":
                from benchmarks import paper_tables
                paper_tables.run(_emit)
            elif section == "banking_ablation":
                from benchmarks import banking_ablation
                banking_ablation.run(_emit)
            elif section == "calyx_bench":
                from benchmarks import calyx_bench
                calyx_bench.run(_emit)
            elif section == "serve_bench":
                from benchmarks import serve_bench
                serve_bench.run(_emit)
            elif section == "resilience_bench":
                from benchmarks import resilience_bench
                resilience_bench.run(_emit)
            elif section == "kernel_bench":
                from benchmarks import kernel_bench
                kernel_bench.run(_emit)
            elif section == "model_profile_bench":
                from benchmarks import model_profile_bench
                model_profile_bench.run(_emit)
            elif section == "roofline_report":
                from benchmarks import roofline_report
                roofline_report.run(_emit)
            else:
                raise ValueError(f"unknown section {section}")
        except Exception as e:
            failures.append(section)
            print(f"# section {section} FAILED: {e}", flush=True)
            traceback.print_exc()
    print(f"# total {time.time() - t0:.1f}s; failures: {failures or 'none'}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
