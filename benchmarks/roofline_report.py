"""Roofline / offload-candidate report (§Roofline source of truth).

Primary source: the committed ``BENCH_model.json`` — measured per-operator
decode-step profiles (``benchmarks/model_profile_bench.py``) joined with
the analytic cost model at the deployment shape and roofline-classed
against the device peaks.  One row per (arch, operator) ranked by
measured share of step time: the Calyx-lowering work order.

Optional enrichment: if dry-run artifacts exist
(``python -m repro.launch.dryrun --all --both``), the whole-model
roofline cells (compute/memory/collective seconds, dominant resource)
are emitted alongside.  Their absence is not an error — the committed
profile is the source of truth; the dry-run sweep is a deeper cut over
shapes and meshes.
"""
from __future__ import annotations

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_MODEL = ROOT / "BENCH_model.json"
ARTIFACTS = ROOT / "artifacts"


def load_model_bench(path: pathlib.Path = BENCH_MODEL):
    return json.loads(path.read_text())


def load_cells(dirname: str):
    cells = []
    d = ARTIFACTS / dirname
    if not d.exists():
        return cells
    for p in sorted(d.glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def run(emit) -> None:
    # -- primary: committed per-operator profiles -------------------------
    bench = load_model_bench()
    for rec in bench["records"]:
        arch = rec["arch"]
        shape = rec["full_shape"]
        for row in rec["offload"]:
            emit(f"roofline_{arch}_{row['op']}", row["wall_us_mean"],
                 f"rank={row['rank']}"
                 f"|share={row['share']:.0%}"
                 f"|flops={row['flops_per_step']:.3e}"
                 f"|bytes={row['bytes_per_step']:.3e}"
                 f"|intensity={row['intensity']:.1f}"
                 f"|bound={row['bound']}"
                 f"@B{shape['batch']}xS{shape['cache_len']}")
        top = rec["offload"][0]
        emit(f"roofline_{arch}_offload_top", 0.0,
             f"{top['op']} ({top['share']:.0%} of step, {top['bound']}"
             f"-bound) -> first Calyx lowering candidate")

    # -- enrichment: dry-run sweep cells when present ---------------------
    for label, dirname in (("base", "dryrun_baseline"),
                           ("opt", "dryrun_opt")):
        for r in load_cells(dirname):
            key = f"roofline_{label}_{r['arch']}_{r['shape']}_{r['mesh']}"
            if r["status"] == "skipped":
                emit(key, 0.0, "SKIP:full-attention @512k (DESIGN.md §4)")
                continue
            if r["status"] != "ok":
                emit(key, 0.0, f"ERROR:{r.get('error', '?')[:80]}")
                continue
            f = r["roofline"]
            emit(key, f["step_time_s"] * 1e6,
                 f"dom={f['dominant']}"
                 f"|compute_s={f['compute_s']:.4f}"
                 f"|memory_s={f['memory_s']:.4f}"
                 f"|collective_s={f['collective_s']:.4f}"
                 f"|useful_frac={f['useful_flops_frac']:.3f}"
                 f"|roofline_frac={f['roofline_frac']:.4f}")
