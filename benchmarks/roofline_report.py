"""Roofline table from dry-run artifacts (§Roofline source of truth)."""
from __future__ import annotations

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1] / "artifacts"


def load_cells(dirname: str):
    cells = []
    d = ROOT / dirname
    if not d.exists():
        return cells
    for p in sorted(d.glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def run(emit) -> None:
    for label, dirname in (("base", "dryrun_baseline"),
                           ("opt", "dryrun_opt")):
        cells = load_cells(dirname)
        if not cells:
            emit(f"roofline_{label}_missing", 0.0,
                 "run `python -m repro.launch.dryrun --all --both` first")
            continue
        for r in cells:
            key = f"roofline_{label}_{r['arch']}_{r['shape']}_{r['mesh']}"
            if r["status"] == "skipped":
                emit(key, 0.0, "SKIP:full-attention @512k (DESIGN.md §4)")
                continue
            if r["status"] != "ok":
                emit(key, 0.0, f"ERROR:{r.get('error', '?')[:80]}")
                continue
            f = r["roofline"]
            emit(key, f["step_time_s"] * 1e6,
                 f"dom={f['dominant']}"
                 f"|compute_s={f['compute_s']:.4f}"
                 f"|memory_s={f['memory_s']:.4f}"
                 f"|collective_s={f['collective_s']:.4f}"
                 f"|useful_frac={f['useful_flops_frac']:.3f}"
                 f"|roofline_frac={f['roofline_frac']:.4f}")
