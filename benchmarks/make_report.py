"""Render EXPERIMENTS.md tables from dry-run artifact directories.

    PYTHONPATH=src:. python -m benchmarks.make_report [baseline_dir] [opt_dir]
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def load(dirname):
    out = {}
    d = ROOT / "artifacts" / dirname
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}T"
    if b >= 1e9:
        return f"{b/1e9:.1f}G"
    return f"{b/1e6:.0f}M"


def dryrun_table(cells, mesh):
    lines = ["| arch | shape | status | chips | HLO flops/dev | bytes/dev | "
             "coll bytes/dev | temp/dev | compile |",
             "|---|---|---|---:|---:|---:|---:|---:|---:|"]
    for (a, s, m), r in sorted(cells.items()):
        if m != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {a} | {s} | SKIP (full attn @512k) | | | | | | |")
            continue
        f = r["roofline"]
        temp = (r.get("memory_analysis") or {}).get("temp_size_in_bytes", 0)
        lines.append(
            f"| {a} | {s} | ok | {r['chips']} | "
            f"{fmt_bytes(f['flops_per_device'])} | "
            f"{fmt_bytes(f['bytes_per_device'])} | "
            f"{fmt_bytes(f['collective_bytes_per_device'])} | "
            f"{fmt_bytes(temp)} | {r['compile_s']:.0f}s |")
    return "\n".join(lines)


def roofline_table(cells, mesh="pod16x16"):
    lines = ["| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | useful frac | roofline frac |",
             "|---|---|---:|---:|---:|---|---:|---:|"]
    for (a, s, m), r in sorted(cells.items()):
        if m != mesh or r["status"] != "ok":
            continue
        f = r["roofline"]
        lines.append(
            f"| {a} | {s} | {f['compute_s']:.3f} | {f['memory_s']:.3f} | "
            f"{f['collective_s']:.4f} | **{f['dominant']}** | "
            f"{f['useful_flops_frac']:.2f} | {f['roofline_frac']:.4f} |")
    return "\n".join(lines)


def compare_table(base, opt, cells_of_interest):
    lines = ["| cell | metric | baseline | optimized | delta |",
             "|---|---|---:|---:|---:|"]
    for (a, s) in cells_of_interest:
        b = base.get((a, s, "pod16x16"))
        o = opt.get((a, s, "pod16x16"))
        if not b or not o or b["status"] != "ok" or o["status"] != "ok":
            continue
        for m in ("compute_s", "memory_s", "collective_s"):
            bb, oo = b["roofline"][m], o["roofline"][m]
            lines.append(f"| {a}×{s} | {m} | {bb:.3f} | {oo:.3f} | "
                         f"{(oo/bb-1)*100:+.1f}% |")
        bt = (b.get("memory_analysis") or {}).get("temp_size_in_bytes", 0)
        ot = (o.get("memory_analysis") or {}).get("temp_size_in_bytes", 0)
        lines.append(f"| {a}×{s} | temp/dev | {fmt_bytes(bt)} | "
                     f"{fmt_bytes(ot)} | {(ot/max(bt,1)-1)*100:+.1f}% |")
        lines.append(f"| {a}×{s} | roofline_frac | "
                     f"{b['roofline']['roofline_frac']:.4f} | "
                     f"{o['roofline']['roofline_frac']:.4f} | "
                     f"{o['roofline']['roofline_frac']/max(b['roofline']['roofline_frac'],1e-9):.2f}x |")
    return "\n".join(lines)


def main():
    base = load(sys.argv[1] if len(sys.argv) > 1 else "dryrun_baseline")
    opt_dir = sys.argv[2] if len(sys.argv) > 2 else "dryrun_opt"
    try:
        opt = load(opt_dir)
    except Exception:
        opt = {}
    print("### Dry-run (single pod 16x16, baseline)\n")
    print(dryrun_table(base, "pod16x16"))
    print("\n### Dry-run (multi-pod 2x16x16, baseline)\n")
    print(dryrun_table(base, "pod2x16x16"))
    print("\n### Roofline (single pod, baseline)\n")
    print(roofline_table(base))
    if opt:
        print("\n### Roofline (single pod, optimized)\n")
        print(roofline_table(opt))
        print("\n### Optimized vs baseline (hillclimbed cells)\n")
        print(compare_table(base, opt, [("qwen2-0.5b", "train_4k"),
                                        ("olmoe-1b-7b", "train_4k"),
                                        ("gemma2-27b", "train_4k")]))


if __name__ == "__main__":
    main()
