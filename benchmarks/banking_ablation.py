"""Ablations of the paper's two techniques (§3.3) + the TPU-side analogue.

1. layout vs branchy banking: cycles, LUTs, instantiated branch arms
   (the c^d blow-up), surviving div/mod units, unprovable hazards.
2. restructured vs duplicated-FSM schedules (par/seq rewrite).
3. unbanked parallelism: port-conflict serialization (why banking exists).
4. resource sharing: bound vs one-unit-per-statement designs — the extra
   column the binding pass adds to the paper's resource table (LUT/DSP
   reduction at identical cycle counts).
5. TPU analogue: MoE banked (static einsum) vs gather dispatch — HLO gather
   op census at small scale.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import affine, banking, calyx, estimator, frontend, pipeline
from repro.core.banking import count_branch_arms, count_divmod_hardware
from repro.core import schedule as SCH


def banking_modes(emit) -> None:
    m = frontend.paper_ffnn()
    for f in (2, 4):
        dl = pipeline.compile_model(m, [(1, 64)], factor=f, mode="layout")
        db = pipeline.compile_model(m, [(1, 64)], factor=f, mode="branchy",
                                    check_hazards=False)
        emit(f"ablate_f{f}_layout_cycles", 0.0, dl.estimate.cycles)
        emit(f"ablate_f{f}_branchy_cycles", 0.0, db.estimate.cycles)
        emit(f"ablate_f{f}_branchy_slowdown", 0.0,
             f"{db.estimate.cycles / dl.estimate.cycles:.2f}x")
        emit(f"ablate_f{f}_branch_arms", 0.0,
             f"layout={count_branch_arms(dl.program)}"
             f"|branchy={count_branch_arms(db.program)}")
        emit(f"ablate_f{f}_divmod_units", 0.0,
             f"layout={count_divmod_hardware(dl.program)}"
             f"|branchy={count_divmod_hardware(db.program)}")
        emit(f"ablate_f{f}_unprovable_hazards", 0.0,
             f"layout={len(dl.hazards)}|branchy={len(db.hazards)}")


def restructure_ablation(emit) -> None:
    m = frontend.paper_ffnn()
    for f in (2, 4):
        d_on = pipeline.compile_model(m, [(1, 64)], factor=f,
                                      restructure=True)
        d_off = pipeline.compile_model(m, [(1, 64)], factor=f,
                                       restructure=False)
        emit(f"restructure_f{f}_shared_cycles", 0.0, d_on.estimate.cycles)
        emit(f"restructure_f{f}_duplicated_cycles", 0.0, d_off.estimate.cycles)
        emit(f"restructure_f{f}_win", 0.0,
             f"{d_off.estimate.cycles / d_on.estimate.cycles:.2f}x")


def unbanked_parallelism(emit) -> None:
    """Par without banking: single-ported memories serialize the arms."""
    g = frontend.trace(frontend.paper_ffnn(), [(1, 64)])
    prog_seq = affine.lower_graph(g)
    cyc_seq = estimator.cycles(calyx.lower_program(prog_seq))
    par = SCH.restructure(SCH.parallelize(affine.lower_graph(g), 2))
    cyc_par_unbanked = estimator.cycles(calyx.lower_program(par))
    banked = banking.apply_banking(par, banking.BankingSpec(factor=2))
    cyc_banked = estimator.cycles(calyx.lower_program(banked))
    emit("portmodel_sequential_cycles", 0.0, cyc_seq)
    emit("portmodel_par_unbanked_cycles", 0.0, cyc_par_unbanked)
    emit("portmodel_par_banked_cycles", 0.0, cyc_banked)
    emit("portmodel_banking_required", 0.0,
         f"unbanked_speedup={cyc_seq / cyc_par_unbanked:.2f}x"
         f"|banked_speedup={cyc_seq / cyc_banked:.2f}x")


def sharing_ablation(emit) -> None:
    """Shared vs unshared resource column: the binding pass must cut LUT+DSP
    sharply at *identical* cycle counts (it only rebinds exclusive groups)."""
    for name, model, shape in (
            ("ffnn", frontend.paper_ffnn(), (1, 64)),
            ("matmul", frontend.Linear(64, 48, bias=False), (1, 64))):
        for f in (2, 4):
            ds = pipeline.compile_model(model, [shape], factor=f, share=True)
            du = pipeline.compile_model(model, [shape], factor=f, share=False)
            if ds.estimate.cycles != du.estimate.cycles:  # survives python -O
                raise RuntimeError(
                    f"sharing must be latency-neutral: {name} f={f} "
                    f"{ds.estimate.cycles} != {du.estimate.cycles}")
            rs, ru = ds.estimate.resources, du.estimate.resources
            cut = 1.0 - (rs["LUT"] + rs["DSP"]) / (ru["LUT"] + ru["DSP"])
            emit(f"share_{name}_f{f}_cycles", 0.0, ds.estimate.cycles)
            emit(f"share_{name}_f{f}_lut", 0.0,
                 f"unshared={ru['LUT']}|shared={rs['LUT']}")
            emit(f"share_{name}_f{f}_dsp", 0.0,
                 f"unshared={ru['DSP']}|shared={rs['DSP']}")
            emit(f"share_{name}_f{f}_lutdsp_cut", 0.0, f"{cut * 100:.1f}%")
            emit(f"share_{name}_f{f}_pools", 0.0, ds.sharing.summary())


def moe_dispatch_hlo(emit) -> None:
    """TPU analogue: banked (layout-embedded) vs gather (branchy) MoE."""
    import dataclasses
    from repro.models import get_config
    from repro.models import params as MP
    from repro.models.moe import moe_block

    cfg = get_config("olmoe-1b-7b").reduced()
    prm = MP.init_params(cfg, seed=0)
    layer0 = jax.tree.map(lambda a: a[0], prm["blocks"])["lyr"]["moe"]
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 32, cfg.d_model)),
                    jnp.float32)
    for mode in ("banked", "gather"):
        c = dataclasses.replace(cfg, moe_dispatch=mode)
        t0 = time.time()
        fn = jax.jit(lambda xx: moe_block(c, layer0, xx)[0])
        out = jax.block_until_ready(fn(x))
        t_first = (time.time() - t0) * 1e6
        t0 = time.time()
        for _ in range(5):
            out = jax.block_until_ready(fn(x))
        us = (time.time() - t0) / 5 * 1e6
        text = fn.lower(x).compile().as_text()
        gathers = text.count(" gather(") + text.count(" dynamic-slice(")
        emit(f"moe_{mode}_us_per_call", us, f"gather_ops={gathers}")


def run(emit) -> None:
    banking_modes(emit)
    restructure_ablation(emit)
    unbanked_parallelism(emit)
    sharing_ablation(emit)
    moe_dispatch_hlo(emit)
